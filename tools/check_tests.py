"""Test-coverage gate: every ``src/repro`` module needs a covering test.

"Covering" is import-level, by design: a module counts as covered when at
least one file under ``tests/`` imports it (``import repro.a.b``,
``from repro.a.b import x``, or ``from repro.a import b`` — including
imports inside the subprocess code strings the multi-device tests ship,
which is why this scans import *text*, not a loaded module graph). That
is deliberately a floor, not a substitute for assertions — its job is to
catch the failure mode where a new subsystem lands with no test file at
all, which line-coverage tooling can't do in CI without running the full
(TPU-gated) matrix.

Modules that are legitimately exercised only through higher layers live
in ``ALLOWLIST`` with a reason. The list is checked both ways: an entry
whose module has gained a covering test (or no longer exists) fails the
gate, so the list can only shrink. New subsystems must ship tests, not
allowlist entries.

    PYTHONPATH=src python tools/check_tests.py         # the CI docs job
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
TESTS = os.path.join(REPO, "tests")

# module -> why import-level coverage is waived. Only shrink this list.
ALLOWLIST = {
    # trivial re-export __init__.py facades; every submodule is tested
    # directly by its own test module
    "repro.core": "trivial re-export __init__ (submodules tested)",
    "repro.data": "trivial re-export __init__ (submodules tested)",
    # exercised through a covered importer, not imported by tests directly
    "repro.models.moe":
        "driven through repro.models.lm (tested) + bench_lm_step",
    "repro.train.supervisor":
        "driven through repro.train.chaos (tested chaos harness)",
    # data-only model presets: dicts consumed through repro.configs.base's
    # loader, which test_models/test_train_substrate exercise
    "repro.configs.arctic_480b": "data-only preset (loader is tested)",
    "repro.configs.deepseek_67b": "data-only preset (loader is tested)",
    "repro.configs.internvl2_76b": "data-only preset (loader is tested)",
    "repro.configs.jamba_1p5_large_398b":
        "data-only preset (loader is tested)",
    "repro.configs.mamba2_1p3b": "data-only preset (loader is tested)",
    "repro.configs.moonshot_v1_16b_a3b":
        "data-only preset (loader is tested)",
    "repro.configs.musicgen_large": "data-only preset (loader is tested)",
    "repro.configs.phi3_medium_14b": "data-only preset (loader is tested)",
    "repro.configs.qwen3_8b": "data-only preset (loader is tested)",
    "repro.configs.starcoder2_3b": "data-only preset (loader is tested)",
    # CLI entry points: exercised as subprocesses by the CI smoke jobs
    # (`python -m repro.launch...`), which import-scanning can't see
    "repro.launch.dryrun": "CLI wrapper, covered by CI dry-run smoke",
    "repro.launch.report": "CLI wrapper over launch.costmodel (tested)",
    "repro.launch.serve": "CLI wrapper, covered by CI serve smoke",
    "repro.launch.train": "CLI wrapper, covered by CI train + workload smoke",
}


def src_modules() -> list:
    """Every importable module under src/repro, dotted."""
    mods = []
    for root, _dirs, files in os.walk(os.path.join(SRC, "repro")):
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(root, f), SRC)
            mod = rel[:-3].replace(os.sep, ".")
            if mod.endswith(".__init__"):
                mod = mod[:-len(".__init__")]
            mods.append(mod)
    return sorted(mods)


_IMPORT = re.compile(r"\bimport\s+(repro(?:\.\w+)+)")
_FROM = re.compile(r"\bfrom\s+(repro(?:\.\w+)*)\s+import\s+"
                   r"(\([^)]*\)|[^\n]*)")


def reexport_map() -> dict:
    """(package, exported name) -> defining module, from each package
    ``__init__.py``'s own ``from repro... import name`` lines — so a test
    importing ``EmbeddingServer`` from ``repro.serve`` credits
    ``repro.serve.server``, not just the facade."""
    out = {}
    for root, _dirs, files in os.walk(os.path.join(SRC, "repro")):
        if "__init__.py" not in files:
            continue
        pkg = os.path.relpath(root, SRC).replace(os.sep, ".")
        with open(os.path.join(root, "__init__.py")) as f:
            text = f.read()
        for m in _FROM.finditer(text):
            for name in re.split(r"[,\s()]+", m.group(2)):
                if name and name.isidentifier():
                    out[(pkg, name)] = m.group(1)
    return out


def covered_modules() -> dict:
    """module -> first test file importing it. Scans raw text so imports
    inside subprocess code strings count (the multi-device idiom)."""
    reexports = reexport_map()
    got = {}
    for fname in sorted(os.listdir(TESTS)):
        if not (fname.endswith(".py") and fname.startswith("test_")):
            continue
        with open(os.path.join(TESTS, fname)) as f:
            text = f.read()
        hits = set()
        for m in _IMPORT.finditer(text):
            hits.add(m.group(1))
        for m in _FROM.finditer(text):
            parent = m.group(1)
            hits.add(parent)
            for name in re.split(r"[,\s()]+", m.group(2)):
                if name and name.isidentifier():
                    hits.add(f"{parent}.{name}")
                    if (parent, name) in reexports:
                        hits.add(reexports[(parent, name)])
        for mod in hits:
            got.setdefault(mod, fname)
    return got


def main() -> int:
    mods = src_modules()
    covered = covered_modules()
    failures = []
    for mod in mods:
        if mod in covered:
            if mod in ALLOWLIST:
                failures.append(
                    f"stale ALLOWLIST entry: {mod} is now covered by "
                    f"tests/{covered[mod]} — remove it from "
                    f"tools/check_tests.py")
            else:
                print(f"  [ok]      {mod}  <- tests/{covered[mod]}")
            continue
        if mod in ALLOWLIST:
            print(f"  [allowed] {mod}  ({ALLOWLIST[mod]})")
            continue
        failures.append(
            f"{mod} has no covering test module — add one under tests/ "
            f"(or, for modules only reachable through higher layers, an "
            f"ALLOWLIST entry with a reason in tools/check_tests.py)")
    for entry in ALLOWLIST:
        if entry not in mods:
            failures.append(
                f"stale ALLOWLIST entry: {entry} no longer exists — "
                f"remove it from tools/check_tests.py")
    if failures:
        print("\ntest-coverage gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\ntest-coverage gate passed: {len(mods)} modules, "
          f"{len(ALLOWLIST)} allowlisted")
    return 0


if __name__ == "__main__":
    sys.exit(main())
