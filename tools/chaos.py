#!/usr/bin/env python
"""Deterministic chaos harness CLI (DESIGN.md §9).

Runs a scripted fault schedule against a supervised W2V run and verifies
recovery is **bit-exact**: the faulted run's final table digest must equal
the fault-free baseline's. Exit status is the contract (0 = recovered
bit-exact and every scheduled fault actually fired; 1 = anything less),
so CI can gate on it directly.

    PYTHONPATH=src python tools/chaos.py --schedule ci
    PYTHONPATH=src python tools/chaos.py --schedule ci --json

Schedules live in ``repro.train.chaos.SCHEDULES``; the ``ci`` one is the
acceptance bar: injected step exceptions, a SIGKILLed prefetch worker, a
truncated checkpoint, and an injected NaN, all in a 10-batch run that
crosses an epoch boundary.
"""
from __future__ import annotations

import argparse
import json
import logging
import sys


def main() -> int:
    from repro.train.chaos import SCHEDULES, run_chaos

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--schedule", default="ci", choices=sorted(SCHEDULES),
                    help="fault script to run (default: ci)")
    ap.add_argument("--backend", default="jnp",
                    help="kernel backend for both runs (default: jnp)")
    ap.add_argument("--json", action="store_true",
                    help="print the full result dict as JSON")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-fault warning logs")
    args = ap.parse_args()

    logging.basicConfig(
        level=logging.ERROR if args.quiet else logging.WARNING,
        format="%(name)s %(message)s")

    sched = SCHEDULES[args.schedule]
    result = run_chaos(sched, backend=args.backend)
    if args.json:
        print(json.dumps(result, indent=1, sort_keys=True))
    else:
        print(f"schedule={args.schedule} batches={result['batches_seen']} "
              f"restarts={result['restarts']} "
              f"rollbacks={result['rollbacks']} heals={result['heals']} "
              f"quarantined={result['ckpt_quarantined']} "
              f"recovery_seconds={result['recovery_seconds']}")
        print(f"baseline_digest={result['baseline_digest']}")
        print(f"final_digest={result['final_digest']}")

    failures = []
    if not result["digest_match"]:
        failures.append("final_digest differs from fault-free baseline")
    if result["faults_fired"] < result["faults_scheduled"]:
        failures.append(
            f"only {result['faults_fired']}/{result['faults_scheduled']} "
            f"scheduled faults fired")
    if sched.kill_worker_at and result["workers_killed"] < 1:
        failures.append("no prefetch worker was actually killed")
    # heals is reported but not gated: a kill can be absorbed either by
    # the pool's own heal path or by a supervisor rollback rebuilding the
    # pipeline first — which one wins is a benign race. The heal path
    # itself is pinned deterministically in tests/test_prefetch.py.
    if sched.truncate_ckpt_at and result["ckpts_truncated"] < 1:
        failures.append("no checkpoint was actually truncated")
    if sched.truncate_ckpt_at and result["ckpt_quarantined"] < 1:
        failures.append("truncated checkpoint was never quarantined")
    if failures:
        print("chaos: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("chaos: recovery is bit-exact — all scheduled faults survived")
    return 0


if __name__ == "__main__":
    sys.exit(main())
