"""Baseline SGNS implementations vs the FULL-W2V oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import matrix_sgns, naive_sgns
from repro.kernels.ref import batch_sgns_ref
from tests.conftest import make_distinct_negs


def _data(rng, V=40, S=2, L=10, N=3, distinct_tokens=False):
    if distinct_tokens:
        tokens = np.stack([
            rng.permutation(V)[:L] for _ in range(S)]).astype(np.int32)
    else:
        tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.full((S,), L, np.int32)
    w_in = rng.normal(size=(V, 128)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, 128)).astype(np.float32) * 0.1
    return w_in, w_out, tokens, negs, lengths


def test_matrix_equals_ringbuffer_on_distinct_tokens(rng):
    """With no short-range token repeats the ring buffer is semantically
    invisible: FULL-W2V == pWord2Vec-style per-window table updates. This is
    the core correctness claim of lifetime reuse (§3.2)."""
    w_in, w_out, tokens, negs, lengths = _data(rng, distinct_tokens=True)
    lr = jnp.float32(0.05)
    a = batch_sgns_ref(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                       jnp.array(negs), jnp.array(lengths), lr, 2)
    b = matrix_sgns(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                    jnp.array(negs), jnp.array(lengths), lr, 2)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), atol=2e-5)


def test_naive_and_matrix_agree_at_small_lr(rng):
    """Per-pair immediate updates vs per-window batched updates differ only
    at O(lr^2): at small lr they converge to the same step."""
    w_in, w_out, tokens, negs, lengths = _data(rng, distinct_tokens=True)
    lr = 1e-4
    a = matrix_sgns(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                    jnp.array(negs), jnp.array(lengths), jnp.float32(lr), 2)
    b = naive_sgns(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                   jnp.array(negs), jnp.array(lengths), jnp.float32(lr), 2)
    d_in = np.abs(np.asarray(a[0]) - np.asarray(b[0])).max()
    step = np.abs(np.asarray(a[0]) - w_in).max()
    assert step > 0
    assert d_in < 0.05 * step + 1e-7


@pytest.mark.parametrize("impl", [matrix_sgns, naive_sgns])
def test_baselines_update_and_stay_finite(rng, impl):
    w_in, w_out, tokens, negs, lengths = _data(rng)
    out = impl(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
               jnp.array(negs), jnp.array(lengths), jnp.float32(0.05), 2)
    assert np.isfinite(np.asarray(out[0])).all()
    assert np.abs(np.asarray(out[0]) - w_in).max() > 1e-5
