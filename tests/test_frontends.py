"""Workload-frontend tests (DESIGN.md §12).

Covers, per frontend (node2vec / doc2vec / subword):
  * jnp-oracle parity against the tiled kernels — T=1 bit-identity (with
    the distinct-negative invariant the repo's other bit-identity tests
    rely on), T=8 within the bounded-staleness tolerance,
  * 2-shard vocab-sharded determinism digests (subprocess mesh),
  * adapter property tests (vendored hypothesis shim): walk determinism
    under p/q extremes and degenerate graphs, n-gram hash round-trip and
    bucket bounds, doc-row window coverage,
  * the data/batching.py document-boundary regression (stream packing
    must flush at document boundaries — windows at sentence start/end
    must not borrow context across documents when a static doc row pads
    the window),
  * serve queryability: doc/node vectors reachable through
    ``EmbeddingIndex``.
"""
from __future__ import annotations

import collections
import hashlib

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro import frontends
from repro.configs.w2v import smoke
from repro.data.batching import BatchingPipeline, plan_tiles
from repro.data.corpus import Corpus
from tests.conftest import make_distinct_negs


def _workload(name, **knobs):
    """A small instance of one registered frontend + its attached pipeline."""
    base = smoke(dim=16, sentences_per_batch=8, max_sentence_len=16,
                 **knobs.pop("cfg", {}))
    defaults = {
        "doc2vec": dict(docs=6, sents_per_doc=5, clusters=3,
                        words_per_cluster=8, mean_len=8),
        "subword": dict(vocab=48, clusters=6, sentences=120, mean_len=10,
                        buckets=64),
        "node2vec": dict(communities=4, nodes_per=6, walks_per_node=2,
                         walk_length=12),
        "w2v": dict(vocab=48, clusters=6, sentences=120, mean_len=10),
    }[name]
    defaults.update(knobs)
    wl = frontends.get(name).build(base, **defaults)
    pipe = BatchingPipeline(wl.corpus, wl.cfg)
    wl.attach(pipe)
    return wl, pipe


WORKLOADS = ("node2vec", "doc2vec", "subword")


# ---------------------------------------------------------------------------
# data/batching.py document-boundary regression (written before the fix).
# ---------------------------------------------------------------------------

def _doc_of(corpus: Corpus):
    """raw token -> owning doc id (tokens are unique per doc here)."""
    owner = {}
    for sent, doc in zip(corpus.sentences, corpus.doc_ids):
        for w in sent:
            owner[w] = doc
    return owner


def test_stream_packing_flushes_at_doc_boundaries():
    """ignore_delimiters packs the encoded stream into pseudo-sentences;
    with per-sentence doc ids attached, that packing must flush at every
    document boundary — otherwise windows near the join borrow context
    from the neighbouring document (and the whole row would carry one doc
    id for tokens of two documents)."""
    corpus = Corpus(
        sentences=[[1, 2, 3], [4, 5], [6, 7, 8], [9, 10], [11, 12, 13]],
        vocab_size=14,
        doc_ids=[0, 0, 1, 1, 2],
    )
    cfg = smoke(ignore_delimiters=True, max_sentence_len=4,
                sentences_per_batch=8)
    pipe = BatchingPipeline(corpus, cfg)
    owner = _doc_of(corpus)
    inv = {i: w for w, i in pipe.vocab.ids.items()}
    rows = 0
    for batch in pipe.batches(epoch=0):
        assert batch.docs is not None
        for s in range(batch.tokens.shape[0]):
            ln = int(batch.lengths[s])
            if ln == 0:
                continue
            rows += 1
            raw = [inv[int(t)] for t in batch.tokens[s, :ln]]
            docs_here = {owner[w] for w in raw}
            # the regression: one packed row (= one kernel sentence, one
            # context window span) must never mix documents
            assert len(docs_here) == 1, (
                f"packed row {raw} spans documents {sorted(docs_here)}")
            assert int(batch.docs[s]) == pipe.vocab.size + docs_here.pop()
    assert rows > 0


def test_doc_rows_follow_sentences_without_packing():
    """Plain (non-packing) mode: every emitted row carries its sentence's
    doc id, mapped into table-extra space (vocab.size + doc)."""
    corpus = Corpus(sentences=[[1, 2, 3, 4], [5, 6], [7, 8, 9]],
                    vocab_size=10, doc_ids=[3, 1, 3])
    cfg = smoke(max_sentence_len=8, sentences_per_batch=4)
    pipe = BatchingPipeline(corpus, cfg)
    owner = _doc_of(corpus)
    inv = {i: w for w, i in pipe.vocab.ids.items()}
    seen = 0
    for batch in pipe.batches(epoch=0):
        for s in range(batch.tokens.shape[0]):
            ln = int(batch.lengths[s])
            if ln == 0:
                # padding rows carry no doc
                assert int(batch.docs[s]) == -1
                continue
            seen += 1
            doc = owner[inv[int(batch.tokens[s, 0])]]
            assert int(batch.docs[s]) == pipe.vocab.size + doc
    assert seen == 3


# ---------------------------------------------------------------------------
# Registry surface + backend gating
# ---------------------------------------------------------------------------

def test_registry_names_w2v_first_and_complete():
    from repro.frontends.registry import FrontendSpec
    ns = frontends.names()
    assert ns[0] == "w2v"
    assert set(ns) == {"w2v", "doc2vec", "node2vec", "subword"}
    assert [s.name for s in frontends.specs()] == list(ns)
    for s in frontends.specs():
        assert isinstance(s, FrontendSpec)
        assert s.description and s.corpus   # the docs table is generated


def test_registry_unknown_frontend_actionable():
    with pytest.raises(ValueError, match="unknown workload frontend"):
        frontends.get("glove")


def test_frontend_steps_reject_incapable_backend():
    """A workload whose steps carry frontend extensions must not resolve
    onto a kernel that would silently drop them (DESIGN.md §12 gating)."""
    from repro.core.trainer import TrainSession
    wl, pipe = _workload("doc2vec")
    with pytest.raises(ValueError, match="frontend feature"):
        TrainSession(pipe, wl.cfg, backend="pallas_pipelined")


def test_builds_accept_and_ignore_foreign_knobs():
    """The CLI hands every workload knob to every frontend; builds must
    take their own and ignore the rest."""
    cfg = smoke(dim=16)
    wl = frontends.get("doc2vec").build(cfg, docs=4, buckets=123, p=9.0)
    assert wl.name == "doc2vec"


# ---------------------------------------------------------------------------
# jnp-oracle parity: sequential vs tiled reference on REAL frontend batches
# (the jnp/jnp_tiled backends *are* these references; pallas kernels are
# gated out by `supports_frontends`).
# ---------------------------------------------------------------------------

def _frontend_step_args(name, rng):
    """One real batch of the workload, with kernel-invariant negatives:
    bit-identity between the sequential and T=1 tiled paths requires the
    per-window distinct-negative invariant (conftest.make_distinct_negs),
    which the production sampler relaxes."""
    wl, pipe = _workload(name)
    batch = next(pipe.batches(pad_len=wl.cfg.resolved_pad_len, epoch=0))
    tokens = np.asarray(batch.tokens)
    lengths = np.asarray(batch.lengths)
    negs = make_distinct_negs(rng, tokens, pipe.vocab.size, 3)
    rows = pipe.table_rows
    w_in = rng.normal(size=(rows, 32)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(rows, 32)).astype(np.float32) * 0.1
    docs = None if batch.docs is None else np.asarray(batch.docs)
    bags = None if batch.bags is None else np.asarray(batch.bags)
    return w_in, w_out, tokens, negs, lengths, docs, bags


def _run_refs(w_in, w_out, tokens, negs, lengths, docs, bags, tile):
    import jax.numpy as jnp

    from repro.kernels.ref import batch_sgns_ref, batch_sgns_tiled_ref
    kw = {}
    if docs is not None:
        kw["static_ids"] = jnp.asarray(docs)
    if bags is not None:
        kw["bags"] = jnp.asarray(bags)
    def common():
        # fresh device tables per call — the refs donate their table args
        return (jnp.asarray(w_in), jnp.asarray(w_out), jnp.asarray(tokens),
                jnp.asarray(negs), jnp.asarray(lengths), jnp.float32(0.05), 2)

    seq = batch_sgns_ref(*common(), **kw)
    plan = plan_tiles(tokens, negs, lengths, tile)
    pa = [jnp.asarray(x) for x in (plan.uniq, plan.scatter,
                                   plan.ucount, plan.strict)]
    tiled = batch_sgns_tiled_ref(*common(), tile, *pa, **kw)
    return seq, tiled


@pytest.mark.parametrize("name", WORKLOADS)
def test_frontend_t1_tiled_bit_identical(name, rng):
    """T=1 tiled path == sequential oracle, bit for bit, with the doc row /
    bag extensions live (the §12 analogue of the kernel acceptance test)."""
    seq, tiled = _run_refs(*_frontend_step_args(name, rng), tile=1)
    assert (np.asarray(seq[0]) == np.asarray(tiled[0])).all()
    assert (np.asarray(seq[1]) == np.asarray(tiled[1])).all()


@pytest.mark.parametrize("name", WORKLOADS)
def test_frontend_t8_tiled_within_tolerance(name, rng):
    """T=8 relaxes ordering inside collision-free tiles; the divergence
    from the sequential oracle must stay O(lr²)-bounded with frontend
    extensions live (doc rows join every tile, bags amplify row reuse)."""
    seq, tiled = _run_refs(*_frontend_step_args(name, rng), tile=8)
    for a, b in zip(seq, tiled):
        a, b = np.asarray(a), np.asarray(b)
        assert np.isfinite(b).all()
        assert np.abs(a - b).max() < 1e-2


# ---------------------------------------------------------------------------
# Determinism: prefetch-worker invariance (in-process) and 2-shard
# vocab-sharded digests (subprocess mesh).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", WORKLOADS)
def test_async_batches_bitwise_equal_sync(name):
    """Frontend batches — docs and bags included — are pure functions of
    (corpus, cfg, epoch, index): any prefetch worker count must reproduce
    the sync stream bit for bit."""
    from repro.data.prefetch import AsyncBatchingPipeline
    wl, pipe = _workload(name)
    ref = list(pipe.batches(pad_len=wl.cfg.resolved_pad_len, epoch=0))
    assert ref
    apipe = AsyncBatchingPipeline(wl.corpus, wl.cfg, vocab=pipe.vocab,
                                  workers=3, depth=2)
    wl.attach(apipe)
    got = list(apipe.batches(pad_len=wl.cfg.resolved_pad_len, epoch=0))
    assert len(got) == len(ref)
    for x, y in zip(ref, got):
        for f in ("tokens", "negs", "lengths", "docs", "bags"):
            a, b = getattr(x, f), getattr(y, f)
            assert (a is None) == (b is None), f
            if a is not None:
                assert np.array_equal(a, b), f


@pytest.mark.parametrize("name", WORKLOADS)
def test_two_shard_digest_deterministic(name, subproc):
    """On a 2-shard mesh each workload must train to the same table digest
    across (a) a repeat run and (b) a 2-worker prefetch run — the sharded
    exchange carries doc rows and bag members (always in the zero-count
    cold tail) without breaking bit-determinism."""
    code = f"""
    import hashlib
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro import frontends
    from repro.configs.w2v import smoke
    from repro.core.trainer import TrainSession
    from repro.data.batching import BatchingPipeline
    from repro.data.prefetch import AsyncBatchingPipeline

    def digest(workers):
        cfg = smoke(dim=16, sentences_per_batch=8, max_sentence_len=16,
                    vocab_shard=True, hot_vocab_frac=0.3)
        wl = frontends.get({name!r}).build(
            cfg, docs=6, sents_per_doc=5, clusters=3, words_per_cluster=8,
            mean_len=8, vocab=48, sentences=120, buckets=64,
            communities=4, nodes_per=6, walks_per_node=2, walk_length=12)
        if workers:
            pipe = AsyncBatchingPipeline(wl.corpus, wl.cfg, workers=workers,
                                         depth=2)
        else:
            pipe = BatchingPipeline(wl.corpus, wl.cfg)
        wl.attach(pipe)
        mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
        s = TrainSession(pipe, wl.cfg, backend="jnp", mesh=mesh)
        assert s.placement is not None and s.placement.n_shards == 2
        s.train(max_batches=2)
        e = np.ascontiguousarray(s.embeddings())
        return hashlib.sha1(e.tobytes()).hexdigest()

    a, b, c = digest(0), digest(0), digest(2)
    assert a == b == c, (a, b, c)
    print("digest", a)
    """
    r = subproc(code, n_devices=2)
    assert r.returncode == 0, r.stderr
    assert "digest" in r.stdout


def test_mixed_precision_tables_compose_with_bags():
    """--tables mixed precision composes with a frontend: the int8 cold
    tail holds the n-gram bucket rows (zero-count ids), and training still
    runs to finite tables."""
    from repro.core.trainer import TrainSession
    wl, pipe = _workload(
        "subword", cfg={"tables": "hot=bf16:frac=0.25,cold=int8,shards=1"})
    sess = TrainSession(pipe, wl.cfg, backend="jnp")
    sess.train(max_batches=2)
    emb = sess.embeddings()
    assert emb.shape[0] == pipe.table_rows
    assert np.isfinite(emb).all()


# ---------------------------------------------------------------------------
# node2vec adapter properties
# ---------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([1e-6, 1e-3, 1.0, 1e3, 1e6]),
       st.sampled_from([1e-6, 1.0, 1e6]))
@settings(max_examples=10, deadline=None)
def test_walk_determinism_under_pq_extremes(seed, p, q):
    """The same keyed rng must reproduce the same walk for any positive
    p/q — including extremes where one bias weight dwarfs the others (the
    cumsum sampler must not degenerate) — and every hop must be an edge."""
    from repro.frontends.node2vec import community_graph, node2vec_walk
    g = community_graph(n_communities=3, nodes_per=5, seed=1)
    walks = [node2vec_walk(g, 2, 20, p, q,
                           np.random.default_rng(
                               np.random.SeedSequence([seed, 7])))
             for _ in range(2)]
    assert walks[0] == walks[1]
    w = walks[0]
    assert len(w) == 20 and all(0 <= v < g.n_nodes for v in w)
    for a, b in zip(w, w[1:]):
        assert b in g.neighbors(a)


def test_walk_degenerate_graphs():
    from repro.frontends.node2vec import Graph, node2vec_walk
    rng = np.random.default_rng(0)
    # isolated node: the walk stops at its sink immediately
    lonely = Graph.from_edges([], n_nodes=1)
    assert node2vec_walk(lonely, 0, 10, 1.0, 1.0, rng) == [0]
    # self-loop-only node: the walk revisits it for the full length (the
    # return weight 1/p applies but there is nowhere else to go)
    loop = Graph.from_edges([(0, 0)], n_nodes=1)
    assert node2vec_walk(loop, 0, 10, 0.25, 4.0, rng) == [0] * 10


def test_walk_corpus_keyed_per_walk_and_rejects_bad_pq():
    from repro.frontends.node2vec import community_graph, walk_corpus
    g = community_graph(n_communities=2, nodes_per=4, seed=0)
    a = walk_corpus(g, walks_per_node=2, walk_length=8, p=0.5, q=2.0, seed=3)
    b = walk_corpus(g, walks_per_node=2, walk_length=8, p=0.5, q=2.0, seed=3)
    assert a.sentences == b.sentences          # pure in (graph, knobs, seed)
    c = walk_corpus(g, walks_per_node=2, walk_length=8, p=0.5, q=2.0, seed=4)
    assert a.sentences != c.sentences          # and the seed matters
    with pytest.raises(ValueError, match="positive"):
        walk_corpus(g, p=0.0, q=1.0)


# ---------------------------------------------------------------------------
# subword adapter properties
# ---------------------------------------------------------------------------

def test_fnv1a_known_answers():
    """Pinned FNV-1a 32-bit vectors: the bucket layout must be identical
    on every host/worker (no PYTHONHASHSEED exposure)."""
    from repro.frontends.subword import fnv1a
    assert fnv1a(b"") == 0x811C9DC5
    assert fnv1a(b"a") == 0xE40C292C
    assert fnv1a(b"foobar") == 0xBF9CF968


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 12),
       st.sampled_from([16, 64, 1024]))
@settings(max_examples=15, deadline=None)
def test_ngram_roundtrip_and_bucket_bounds(seed, length, buckets):
    """The minn-gram sequence reconstructs ``<word>`` exactly (no n-gram
    lost or reordered), and every hashed bucket is in range and pure."""
    from repro.frontends.subword import ngram_bucket, word_ngrams
    rng = np.random.default_rng(seed)
    word = "".join(chr(97 + int(x)) for x in rng.integers(0, 26, length))
    grams = word_ngrams(word, minn=3, maxn=5)
    w = f"<{word}>"
    n3 = [g for g in grams if len(g) == 3]
    assert "".join([n3[0]] + [g[-1] for g in n3[1:]]) == w
    assert all(3 <= len(g) <= 5 and g in w for g in grams)
    for g in grams:
        b = ngram_bucket(g, buckets)
        assert 0 <= b < buckets
        assert b == ngram_bucket(g, buckets)


def test_bag_table_membership_and_truncation():
    from repro.frontends.subword import build_bag_table, word_ngrams
    _, pipe = _workload("subword", buckets=32)
    V, table = pipe.vocab.size, pipe.bag_table
    assert table.shape[0] == V and pipe.extra_rows == 32
    # member 0 is the word's own row; the rest are in-range bucket rows
    np.testing.assert_array_equal(table[:, 0], np.arange(V))
    tail = table[:, 1:]
    valid = tail >= 0
    assert ((tail[valid] >= V) & (tail[valid] < V + 32)).all()
    # -1 padding is a strict suffix per row, and the valid count is exactly
    # 1 + #ngrams (duplicate buckets are *kept* — fastText semantics)
    inv = {i: w for w, i in pipe.vocab.ids.items()}
    for i in range(V):
        row_valid = table[i] >= 0
        k = int(row_valid.sum())
        assert row_valid[:k].all() and not row_valid[k:].any()
        assert k == 1 + len(word_ngrams(str(inv[i])))
    capped = build_bag_table(pipe.vocab, 32, max_members=3)
    assert capped.shape[1] == 3
    np.testing.assert_array_equal(capped[:, 0], np.arange(V))


# ---------------------------------------------------------------------------
# doc2vec adapter properties
# ---------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1), st.booleans())
@settings(max_examples=6, deadline=None)
def test_doc_row_coverage_exact(seed, pack):
    """Every encoded token of every document reaches the kernels in a row
    labelled with that document's table row — across both packing modes,
    with nothing dropped, duplicated, or relabelled. This is the window-
    coverage precondition: the kernel injects ``docs[s]`` into every
    window of row s, so row labels ⇒ full per-document window coverage."""
    from repro.frontends.doc2vec import document_corpus
    rng = np.random.default_rng(seed)
    corpus = document_corpus(n_docs=int(rng.integers(2, 6)),
                             sents_per_doc=int(rng.integers(2, 5)),
                             n_clusters=2, words_per_cluster=6,
                             mean_len=6, seed=seed)
    cfg = smoke(dim=16, sentences_per_batch=4, max_sentence_len=8,
                ignore_delimiters=pack, min_count=1, subsample_t=0.0)
    pipe = BatchingPipeline(corpus, cfg)
    # both modes split streams into max-len rows and drop a trailing
    # length-1 chunk (it has no window); packing chunks per *document*,
    # plain mode per sentence
    units = collections.defaultdict(list)
    for i, (sent, doc) in enumerate(zip(corpus.sentences, corpus.doc_ids)):
        units[doc if pack else (doc, i)].extend(
            pipe.vocab.ids[w] for w in sent)
    want = collections.Counter()
    for key, toks in units.items():
        doc = key if pack else key[0]
        if len(toks) % cfg.max_sentence_len == 1:
            toks = toks[:-1]
        for t in toks:
            want[(doc, t)] += 1
    got = collections.Counter()
    for batch in pipe.batches(epoch=0):
        for s in range(batch.tokens.shape[0]):
            ln = int(batch.lengths[s])
            if ln == 0:
                continue
            doc = int(batch.docs[s]) - pipe.vocab.size
            assert doc >= 0
            for t in batch.tokens[s, :ln]:
                got[(doc, int(t))] += 1
    assert got == want


# ---------------------------------------------------------------------------
# Serve queryability: doc vectors through EmbeddingIndex
# ---------------------------------------------------------------------------

def test_doc_vectors_queryable_via_embedding_index():
    """A doc2vec session serves through the unchanged serving stack: the
    index covers the doc rows past the vocabulary, its table is the
    normalized trainer table, and sharded top-k over *doc* query ids
    matches the dense oracle exactly."""
    from repro.core.trainer import TrainSession
    from repro.serve.index import EmbeddingIndex
    from repro.serve.query import dense_topk, make_topk_fn
    wl, pipe = _workload("doc2vec")
    sess = TrainSession(pipe, wl.cfg, backend="jnp")
    sess.train(max_batches=3)
    idx = EmbeddingIndex.from_session(sess)
    V = pipe.vocab.size
    assert idx.vocab_size == pipe.table_rows == V + 6
    emb = sess.embeddings()
    norm = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True),
                            1e-12)
    np.testing.assert_allclose(idx.dense_embeddings(), norm, atol=1e-6)
    doc_ids = np.arange(V, V + 6, dtype=np.int32)
    fn = make_topk_fn(idx.placement, idx.mesh, mode="nn", k=5)
    got_ids, got_sc = fn(idx.hot, idx.cold, doc_ids)
    want_ids, want_sc = dense_topk(idx.dense_embeddings(), doc_ids, k=5,
                                   mode="nn")
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got_sc), want_sc, atol=1e-6)
