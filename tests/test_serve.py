"""Serving subsystem (DESIGN.md §10): index loading, sharded top-k
parity, snapshot hot-swap, request batching, and the serve chaos bar."""
import os
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
from jax.sharding import Mesh

from repro.distributed.vocab_placement import VocabPlacement
from repro.serve import (EmbeddingIndex, EmbeddingServer, SnapshotWatcher,
                         dense_topk, make_topk_fn)
from repro.serve.chaos import SCHEDULES, _publish, run_serve_chaos
from repro.serve.index import _restripe
from repro.train import checkpoint as ckpt

V, HOT, D = 64, 12, 16


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _table(seed=0, v=V, d=D):
    return np.random.default_rng(seed).standard_normal(
        (v, d)).astype(np.float32)


def _index(seed=0, v=V, hot=HOT, d=D, step=0):
    placement = VocabPlacement(vocab_size=v, hot=hot, n_shards=1)
    h, c = placement.split(_table(seed, v, d))
    return EmbeddingIndex._stage(placement, h, c, _mesh1(), step=step)


# -- index construction -------------------------------------------------------
def test_index_rows_normalized():
    idx = _index()
    dense = idx.dense_embeddings()
    np.testing.assert_allclose(np.linalg.norm(dense, axis=1),
                               np.ones(V), atol=1e-5)


def test_index_load_split_checkpoint_without_merge(tmp_path, monkeypatch):
    """Loading a split checkpoint restores only the input-table leaves
    and never calls VocabPlacement.merge (the no-(V,d)-reassembly
    contract)."""
    d = str(tmp_path)
    table = _table(1)
    placement = VocabPlacement(vocab_size=V, hot=HOT, n_shards=1)
    _publish(d, 30, table, placement)

    def boom(*a, **k):
        raise AssertionError("serving load reassembled the full table")
    monkeypatch.setattr(VocabPlacement, "merge", boom)
    idx = EmbeddingIndex.load(d)
    assert idx.step == 30 and idx.vocab_size == V
    assert idx.placement == placement
    monkeypatch.undo()
    norm = table / np.maximum(
        np.linalg.norm(table, axis=1, keepdims=True), 1e-12)
    np.testing.assert_allclose(idx.dense_embeddings(), norm, atol=1e-6)


def test_index_load_replicated_checkpoint(tmp_path):
    """A replicated (w_in/w_out) checkpoint is split under a prefix-head
    placement at load time."""
    d = str(tmp_path)
    table = _table(2)
    ckpt.save(d, 5, {"w_in": table, "w_out": table * 0.5})
    idx = EmbeddingIndex.load(d, hot_frac=0.25)
    assert idx.placement.hot == 16 and idx.n_shards == 1
    norm = table / np.maximum(
        np.linalg.norm(table, axis=1, keepdims=True), 1e-12)
    np.testing.assert_allclose(idx.dense_embeddings(), norm, atol=1e-6)


def test_restripe_permutes_between_layouts():
    """Elastic serving: re-striping cold rows between shard counts is a
    pure permutation — merge(src) == merge(dst) row for row."""
    table = _table(3)
    src = VocabPlacement(vocab_size=V, hot=HOT, n_shards=4)
    dst = VocabPlacement(vocab_size=V, hot=HOT, n_shards=2)
    hot, cold_src = src.split(table)
    cold_dst = _restripe(cold_src, src, dst)
    np.testing.assert_array_equal(dst.merge(hot, cold_dst), table)


def test_index_load_restripes_on_shard_count_change(tmp_path):
    """A checkpoint written on 2 shards serves on 1 without reassembly:
    the dense views agree exactly."""
    d = str(tmp_path)
    table = _table(4)
    _publish(d, 7, table, VocabPlacement(vocab_size=V, hot=HOT, n_shards=2))
    idx = EmbeddingIndex.load(d)      # 1-device mesh -> 1-shard layout
    assert idx.n_shards == 1
    norm = table / np.maximum(
        np.linalg.norm(table, axis=1, keepdims=True), 1e-12)
    np.testing.assert_allclose(idx.dense_embeddings(), norm, atol=1e-6)


def test_index_load_no_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        EmbeddingIndex.load(str(tmp_path / "empty"))


# -- sharded top-k parity -----------------------------------------------------
def test_topk_parity_boundary_ids_1shard():
    idx = _index()
    dense = idx.dense_embeddings()
    # hot/cold boundary: last hot id, first/second cold ids, edges
    ids = np.array([0, HOT - 1, HOT, HOT + 1, V - 1], np.int32)
    fn = make_topk_fn(idx.placement, idx.mesh, mode="nn", k=6)
    got_ids, got_sc = fn(idx.hot, idx.cold, ids)
    want_ids, want_sc = dense_topk(dense, ids, k=6, mode="nn")
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got_sc), want_sc, atol=1e-6)


def test_topk_analogy_parity_1shard():
    idx = _index(5)
    dense = idx.dense_embeddings()
    triples = np.array([[0, 1, 2], [HOT - 1, HOT, HOT + 1],
                        [V - 1, 0, HOT]], np.int32)
    fn = make_topk_fn(idx.placement, idx.mesh, mode="analogy", k=4)
    got_ids, got_sc = fn(idx.hot, idx.cold, triples)
    want_ids, want_sc = dense_topk(dense, triples, k=4, mode="analogy")
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got_sc), want_sc, atol=1e-6)


def test_topk_ties_break_by_id():
    """Duplicate rows produce tied scores; both paths must rank the
    lower id first (the lexicographic tie-break parity depends on)."""
    table = _table(6)
    table[HOT + 3] = table[2]           # a cold duplicate of a hot row
    placement = VocabPlacement(vocab_size=V, hot=HOT, n_shards=1)
    h, c = placement.split(table)
    idx = EmbeddingIndex._stage(placement, h, c, _mesh1())
    ids = np.array([5, 40], np.int32)
    fn = make_topk_fn(placement, idx.mesh, mode="nn", k=V - 1)
    got_ids, _ = fn(idx.hot, idx.cold, ids)
    want_ids, _ = dense_topk(idx.dense_embeddings(), ids, k=V - 1)
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)


def test_topk_excludes_query_words():
    idx = _index(7)
    ids = np.arange(8, dtype=np.int32)
    fn = make_topk_fn(idx.placement, idx.mesh, mode="nn", k=5)
    got_ids, _ = fn(idx.hot, idx.cold, ids)
    for q, row in zip(ids, np.asarray(got_ids)):
        assert q not in row


def test_topk_k_too_large_raises():
    idx = _index()
    with pytest.raises(ValueError):
        make_topk_fn(idx.placement, idx.mesh, k=V + 1)
    with pytest.raises(ValueError):
        make_topk_fn(idx.placement, idx.mesh, mode="cosmul")


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1),     # seed
       st.integers(24, 80),           # vocab
       st.integers(2, 16),            # hot head
       st.integers(1, 8),             # k
       st.integers(1, 6))             # query batch
def test_topk_parity_property_1shard(seed, v, hot, k, b):
    rng = np.random.default_rng(seed)
    hot = min(hot, v - 2)
    table = rng.standard_normal((v, 8)).astype(np.float32)
    placement = VocabPlacement(vocab_size=v, hot=hot, n_shards=1)
    h, c = placement.split(table)
    idx = EmbeddingIndex._stage(placement, h, c, _mesh1())
    ids = rng.integers(v, size=b).astype(np.int32)
    fn = make_topk_fn(placement, idx.mesh, mode="nn", k=k)
    got_ids, got_sc = fn(idx.hot, idx.cold, ids)
    want_ids, want_sc = dense_topk(idx.dense_embeddings(), ids, k=k)
    np.testing.assert_array_equal(np.asarray(got_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got_sc), want_sc, atol=1e-6)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_topk_parity_multishard(subproc, n_shards):
    """Property-style parity across real shard counts (fake devices):
    random ids plus the hot/cold boundary, nn and analogy."""
    r = subproc(f"""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.distributed.vocab_placement import VocabPlacement
        from repro.serve.index import EmbeddingIndex
        from repro.serve.query import dense_topk, make_topk_fn
        n = {n_shards}
        mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
        for seed in range(3):
            rng = np.random.default_rng(seed)
            v = int(rng.integers(40, 90)); hot = int(rng.integers(4, 14))
            table = rng.standard_normal((v, 8)).astype(np.float32)
            pl = VocabPlacement(vocab_size=v, hot=hot, n_shards=n)
            h, c = pl.split(table)
            idx = EmbeddingIndex._stage(pl, h, c, mesh)
            dense = idx.dense_embeddings()
            ids = rng.integers(v, size=9).astype(np.int32)
            ids[:4] = [hot - 1, hot, hot + 1, v - 1]
            fn = make_topk_fn(pl, mesh, mode="nn", k=6)
            gi, gs = fn(idx.hot, idx.cold, ids)
            wi, ws = dense_topk(dense, ids, k=6)
            assert np.array_equal(np.asarray(gi), wi), (seed, gi, wi)
            assert np.allclose(np.asarray(gs), ws, atol=1e-6)
            tri = rng.integers(v, size=(4, 3)).astype(np.int32)
            fa = make_topk_fn(pl, mesh, mode="analogy", k=5)
            gi, gs = fa(idx.hot, idx.cold, tri)
            wi, ws = dense_topk(dense, tri, k=5, mode="analogy")
            assert np.array_equal(np.asarray(gi), wi), (seed, gi, wi)
            assert np.allclose(np.asarray(gs), ws, atol=1e-6)
        print("MULTISHARD_PARITY_OK")
    """, n_devices=n_shards)
    assert "MULTISHARD_PARITY_OK" in r.stdout, r.stdout + r.stderr


# -- session accessors --------------------------------------------------------
def _tiny_session(vocab_shard):
    from repro.configs.w2v import smoke
    from repro.core.trainer import TrainSession
    from repro.data.batching import BatchingPipeline
    from repro.data.corpus import synthetic_cluster_corpus

    cfg = smoke(epochs=1, dim=16, vocab_shard=vocab_shard)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=120, mean_len=8, seed=0)
    sess = TrainSession(BatchingPipeline(corpus, cfg), cfg, backend="jnp")
    sess.train(max_batches=2)
    return sess


def test_embeddings_sharded_no_gather():
    sess = _tiny_session(vocab_shard=True)
    hot, cold, placement = sess.embeddings_sharded()
    assert placement is sess.placement
    assert hot.shape == (placement.hot, 16)
    assert cold.shape == (placement.cold_pad, 16)
    np.testing.assert_array_equal(
        placement.merge(np.asarray(hot), np.asarray(cold)),
        sess.embeddings())


def test_embeddings_sharded_replicated_session():
    sess = _tiny_session(vocab_shard=False)
    full, cold, placement = sess.embeddings_sharded()
    assert cold is None and placement is None
    np.testing.assert_array_equal(np.asarray(full), sess.embeddings())


@pytest.mark.parametrize("vocab_shard", [False, True])
def test_from_session_matches_dense(vocab_shard):
    sess = _tiny_session(vocab_shard)
    idx = EmbeddingIndex.from_session(sess)
    e = sess.embeddings()
    norm = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-12)
    np.testing.assert_allclose(idx.dense_embeddings(), norm, atol=1e-6)


# -- snapshot watcher ---------------------------------------------------------
def test_watcher_swaps_and_tolerates_corrupt(tmp_path):
    d = str(tmp_path)
    placement = VocabPlacement(vocab_size=V, hot=HOT, n_shards=1)
    _publish(d, 10, _table(8), placement)
    w = SnapshotWatcher(d, poll_s=0.01)
    assert w.poll_once() and w.current().step == 10

    # newer-but-corrupt checkpoint: swap refused, old snapshot serves on
    _publish(d, 20, _table(9), placement)
    npz = os.path.join(d, "step_00000020", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    w.poll_once()
    assert w.current().step == 10 and w.load_failures >= 1

    # a good one after it is picked up (corrupt step was quarantined)
    _publish(d, 30, _table(10), placement)
    assert w.poll_once() and w.current().step == 30
    assert w.swaps == 2


def test_watcher_crash_and_restart(tmp_path):
    d = str(tmp_path)
    placement = VocabPlacement(vocab_size=V, hot=HOT, n_shards=1)
    _publish(d, 10, _table(11), placement)
    w = SnapshotWatcher(d, poll_s=0.01)
    with w:
        w.wait_ready(timeout=30)
        w.inject_crash()
        deadline = time.monotonic() + 10
        while w.alive and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not w.alive and w.crashes == 1
        assert w.current().step == 10        # serving survives the crash
        _publish(d, 20, _table(12), placement)
        w.start()                            # restart picks up missed step
        deadline = time.monotonic() + 10
        while w.current().step != 20 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert w.current().step == 20


def test_watcher_current_before_ready_raises(tmp_path):
    w = SnapshotWatcher(str(tmp_path), poll_s=0.01)
    with pytest.raises(RuntimeError):
        w.current()


# -- server batching ----------------------------------------------------------
def test_server_coalesces_and_answers(tmp_path):
    idx = _index(13, step=42)
    dense = idx.dense_embeddings()
    with EmbeddingServer(idx, batch_size=8, deadline_ms=20.0,
                         k=4) as server:
        reqs = [server.submit("nn", np.array([i], np.int32))
                for i in range(8)]
        results = [r.wait(30.0) for r in reqs]
        # a full row budget arriving within the deadline rides one batch
        assert server.batches <= 2
        for i, res in enumerate(results):
            assert res.snapshot_step == 42
            want_ids, want_sc = dense_topk(dense, np.array([i], np.int32),
                                           k=4)
            np.testing.assert_array_equal(res.ids, want_ids)
            np.testing.assert_allclose(res.scores, want_sc, atol=1e-6)


def test_server_mixed_kinds_never_share_a_batch():
    idx = _index(14)
    with EmbeddingServer(idx, batch_size=16, deadline_ms=5.0,
                         k=3) as server:
        nn = server.submit("nn", np.array([1, 2], np.int32))
        an = server.submit("analogy", np.array([[1, 2, 3]], np.int32))
        r_nn, r_an = nn.wait(30.0), an.wait(30.0)
        assert r_nn.ids.shape == (2, 3)
        assert r_an.ids.shape == (1, 3)
        assert server.batches == 2


def test_server_close_drains_pending():
    idx = _index(15)
    server = EmbeddingServer(idx, batch_size=4, deadline_ms=1.0, k=3)
    reqs = [server.submit("nn", np.array([i % V], np.int32))
            for i in range(25)]
    server.close()
    assert all(r.event.is_set() for r in reqs)          # zero dropped
    assert server.served == 25
    with pytest.raises(RuntimeError):
        server.submit("nn", np.array([0], np.int32))


def test_server_rejects_bad_requests():
    idx = _index(16)
    with EmbeddingServer(idx, batch_size=4, k=3) as server:
        with pytest.raises(ValueError):
            server.submit("nn", np.arange(5, dtype=np.int32))   # > batch
        with pytest.raises(ValueError):
            server.submit("cosmul", np.array([0], np.int32))
        with pytest.raises(ValueError):
            server.neighbors(np.array([0], np.int32), k=99)


def test_server_concurrent_submitters():
    idx = _index(17)
    dense = idx.dense_embeddings()
    errors = []

    def client(seed):
        try:
            rng = np.random.default_rng(seed)
            with_ids = rng.integers(V, size=3).astype(np.int32)
            res = server.neighbors(with_ids, timeout=30.0)
            want_ids, _ = dense_topk(dense, with_ids, k=5)
            assert np.array_equal(res.ids, want_ids)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    with EmbeddingServer(idx, batch_size=8, deadline_ms=2.0,
                         k=5) as server:
        threads = [threading.Thread(target=client, args=(s,))
                   for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30.0)
    assert not errors, errors


# -- chaos bar ----------------------------------------------------------------
def test_serve_chaos_ci_schedule_zero_dropped_zero_torn():
    rep = run_serve_chaos(SCHEDULES["ci"], timeout=30.0)
    assert rep["dropped"] == 0, rep
    assert rep["torn"] == 0, rep
    assert rep["errors"] == 0, rep
    assert rep["crashes"] == len(SCHEDULES["ci"].crash_at)
    assert rep["swaps"] >= 2                  # live swap + post-restart swap
    assert rep["steps_served"] >= 2           # answers from >1 snapshot
    assert rep["final_step_served"] == 10 * len(SCHEDULES["ci"].publish_at)
