"""Pallas FULL-W2V kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis-generated sentences (interpret mode on CPU)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.kernels.fullw2v import fullw2v_pallas
from repro.kernels.ref import batch_sgns_ref, sentence_sgns_ref
from tests.conftest import make_distinct_negs


def _run_both(w_in, w_out, tokens, negs, lengths, lr, w_f):
    a = batch_sgns_ref(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                       jnp.array(negs), jnp.array(lengths),
                       jnp.float32(lr), w_f)
    b = fullw2v_pallas(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                       jnp.array(negs), jnp.array(lengths),
                       jnp.float32(lr), w_f, interpret=True)
    return a, b


@pytest.mark.parametrize("d", [128, 256])
@pytest.mark.parametrize("w_f", [1, 2, 3])
def test_kernel_matches_ref_sweep(rng, d, w_f):
    V, S, L, N = 40, 2, 10, 3
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.array([L, L - 3], dtype=np.int32)
    (a_in, a_out), (b_in, b_out) = _run_both(
        w_in, w_out, tokens, negs, lengths, 0.05, w_f)
    np.testing.assert_allclose(np.asarray(a_in), np.asarray(b_in),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a_out), np.asarray(b_out),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("length", [1, 2, 3, 5, 7])
def test_kernel_edge_lengths(rng, length):
    """Sentences shorter than the ring buffer exercise preload/flush edges."""
    V, d, L, N, w_f = 30, 128, 8, 2, 3
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(1, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.array([length], dtype=np.int32)
    (a_in, a_out), (b_in, b_out) = _run_both(
        w_in, w_out, tokens, negs, lengths, 0.1, w_f)
    np.testing.assert_allclose(np.asarray(a_in), np.asarray(b_in),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a_out), np.asarray(b_out),
                               atol=2e-5, rtol=1e-4)


@given(
    st.integers(2, 20),       # vocab (small -> heavy token repetition)
    st.integers(1, 12),       # max sentence length
    st.integers(1, 3),        # negatives
    st.integers(1, 3),        # w_f
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_kernel_matches_ref_hypothesis(vocab, L, n_neg, w_f, seed):
    if vocab <= n_neg:
        vocab = n_neg + 2
    rng = np.random.default_rng(seed)
    d = 128
    w_in = rng.normal(size=(vocab, d)).astype(np.float32) * 0.2
    w_out = rng.normal(size=(vocab, d)).astype(np.float32) * 0.2
    tokens = rng.integers(0, vocab, size=(1, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, vocab, n_neg)
    lengths = np.array([rng.integers(1, L + 1)], dtype=np.int32)
    (a_in, a_out), (b_in, b_out) = _run_both(
        w_in, w_out, tokens, negs, lengths, 0.05, w_f)
    np.testing.assert_allclose(np.asarray(a_in), np.asarray(b_in),
                               atol=3e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(a_out), np.asarray(b_out),
                               atol=3e-5, rtol=2e-4)


def test_kernel_updates_are_nontrivial(rng):
    V, d, L, N, w_f = 20, 128, 6, 2, 2
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(1, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    b_in, b_out = fullw2v_pallas(
        jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
        jnp.array(negs), jnp.array([L], np.int32), jnp.float32(0.1), w_f,
        interpret=True)
    assert float(jnp.abs(b_in - w_in).max()) > 1e-4
    assert float(jnp.abs(b_out - w_out).max()) > 1e-4
    assert np.isfinite(np.asarray(b_in)).all()


def test_sentence_ref_sequentiality(rng):
    """Batch result == folding sentences one at a time (strict ordering)."""
    V, d, S, L, N, w_f = 25, 128, 3, 6, 2, 2
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.full((S,), L, np.int32)
    lr = jnp.float32(0.05)

    a_in, a_out = batch_sgns_ref(jnp.array(w_in), jnp.array(w_out),
                                 jnp.array(tokens), jnp.array(negs),
                                 jnp.array(lengths), lr, w_f)
    c_in, c_out = jnp.array(w_in), jnp.array(w_out)
    for s in range(S):
        c_in, c_out = sentence_sgns_ref(c_in, c_out, jnp.array(tokens[s]),
                                        jnp.array(negs[s]),
                                        jnp.int32(L), lr, w_f)
    np.testing.assert_allclose(np.asarray(a_in), np.asarray(c_in), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a_out), np.asarray(c_out), atol=1e-6)


def test_pipelined_kernel_matches_ref(rng):
    """§3.1 prefetch variant: double-buffered negative loads overlap the
    window GEMMs; must stay bit-identical to the oracle."""
    V, d, S, L, N, w_f = 40, 128, 3, 12, 3, 2
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.array([L, 7, 1], dtype=np.int32)
    a_in, a_out = batch_sgns_ref(
        jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
        jnp.array(negs), jnp.array(lengths), jnp.float32(0.05), w_f)
    b_in, b_out = fullw2v_pallas(
        jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
        jnp.array(negs), jnp.array(lengths), jnp.float32(0.05), w_f,
        interpret=True, pipeline=True)
    np.testing.assert_allclose(np.asarray(a_in), np.asarray(b_in),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a_out), np.asarray(b_out),
                               atol=2e-5, rtol=1e-4)


def test_pipelined_kernel_conflict_path(rng):
    """Adjacent windows sharing output rows exercise the hazard branch
    (conflicting rows are loaded synchronously after write-back)."""
    V, d, L, N, w_f = 30, 128, 8, 2, 2
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(1, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    # force window t+1's first negative == window t's target (hazard)
    for t in range(L - 1):
        cand = tokens[0, t]
        if cand != tokens[0, t + 1] and cand not in negs[0, t + 1, 1:]:
            negs[0, t + 1, 0] = cand
    lengths = np.array([L], dtype=np.int32)
    a = batch_sgns_ref(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                       jnp.array(negs), jnp.array(lengths),
                       jnp.float32(0.08), w_f)
    b = fullw2v_pallas(jnp.array(w_in), jnp.array(w_out), jnp.array(tokens),
                       jnp.array(negs), jnp.array(lengths),
                       jnp.float32(0.08), w_f, interpret=True, pipeline=True)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               atol=2e-5, rtol=1e-4)
