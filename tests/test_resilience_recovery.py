"""Recovery exactness (DESIGN.md §9): supervised runs that survive
injected step failures, NaN'd tables, truncated/partial checkpoints, and
poison batches must end **bit-identical** to a fault-free run — plus unit
coverage for the resilience primitives the supervisor is built from
(RetryPolicy.reset_after, Watchdog exception chaining, StragglerMonitor
decay/eviction, crash-atomic checkpoint recovery)."""
import hashlib
import json
import os
import time

import numpy as np
import pytest

from repro.configs.w2v import smoke
from repro.core.trainer import TrainSession
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus
from repro.train import checkpoint as ckpt
from repro.train.resilience import (FailureInjector, RetryPolicy,
                                    StepTimeout, StragglerMonitor, Watchdog,
                                    run_with_recovery)


def _digest(state) -> str:
    h = hashlib.sha1()
    h.update(np.asarray(state.w_in).tobytes())
    h.update(np.asarray(state.w_out).tobytes())
    return h.hexdigest()


@pytest.fixture(scope="module")
def workload():
    """Tiny 2-epoch workload (5 batches/epoch) + its fault-free digest."""
    cfg = smoke(epochs=2, dim=32, sentences_per_batch=64)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=300, mean_len=10, seed=0)
    vocab = BatchingPipeline(corpus, cfg).vocab
    base = TrainSession(BatchingPipeline(corpus, cfg, vocab=vocab), cfg,
                        backend="jnp")
    base.train()
    return cfg, corpus, vocab, _digest(base.state), base.state.batches_seen


def _session(workload, tmp_path, **kw):
    cfg, corpus, vocab, _, _ = workload
    kw.setdefault("ckpt_every", 2)
    return TrainSession(BatchingPipeline(corpus, cfg, vocab=vocab), cfg,
                        backend="jnp", ckpt_dir=str(tmp_path / "ckpt"),
                        **kw)


# ------------------------------------------------------- supervised recovery
def test_injected_failures_recover_bit_exact(workload, tmp_path):
    """Step exceptions mid-epoch AND across the epoch boundary: restore +
    keyed-randomness replay reproduces the fault-free run bit for bit."""
    cfg, corpus, vocab, base_digest, n = workload
    inj = FailureInjector([3, 7])  # batch 3: mid-epoch-0; 7: mid-epoch-1
    sess = _session(workload, tmp_path,
                    on_metrics=lambda m: inj.check(m.batches_seen))
    sess.train_resilient(backoff_s=0.0)
    assert sess.state.batches_seen == n
    assert _digest(sess.state) == base_digest
    r = sess.last_report
    assert r.restarts == 2 and r.rollbacks == 2
    assert r.recovery_seconds > 0


def test_nan_health_rollback_bit_exact(workload, tmp_path):
    """Injected table NaN: the health probe catches it, rollback restores
    the last clean checkpoint, and the replay is bit-exact."""
    import jax.numpy as jnp

    cfg, corpus, vocab, base_digest, n = workload
    fired = []

    def poison(state):
        if state.batches_seen == 5 and not fired:
            fired.append(True)
            state.w_in = state.w_in.at[0, 0].set(jnp.nan)

    sess = _session(workload, tmp_path, on_batch=poison)
    sess.train_resilient(health_every=1, backoff_s=0.0)
    assert _digest(sess.state) == base_digest
    assert sess.last_report.health_failures == 1
    assert sess.last_report.rollbacks >= 1


def test_poisoned_checkpoint_is_quarantined(workload, tmp_path):
    """A checkpoint written AFTER corruption landed (coarse health probe)
    fails the post-restore probe: the supervisor quarantines it and falls
    back to the older clean one — still ending bit-exact."""
    import jax.numpy as jnp

    cfg, corpus, vocab, base_digest, n = workload
    fired = []

    def poison(state):
        # batch 3: no checkpoint due, and health_every=2 probes only at
        # even batches — so ckpt@4 is saved from already-NaN tables
        if state.batches_seen == 3 and not fired:
            fired.append(True)
            state.w_in = state.w_in.at[0, 0].set(jnp.nan)

    sess = _session(workload, tmp_path, on_batch=poison)
    sess.train_resilient(health_every=2, backoff_s=0.0)
    assert _digest(sess.state) == base_digest
    assert sess.last_report.ckpt_quarantined >= 1


def test_poison_skip_equals_never_training_that_batch(workload, tmp_path):
    """skip_poison: a batch that corrupts the tables every time it is
    trained gets excised on replay — counted, counters advanced, and the
    result is bit-identical to a run that never trained it at all."""
    import jax.numpy as jnp

    cfg, corpus, vocab, base_digest, n = workload
    sess = _session(workload, tmp_path)

    def poison(m):
        # a "truly poison" batch: corrupts whenever TRAINED (not skipped)
        if m.batches_seen == 5 and not m.skipped:
            s = sess.state
            s.w_in = s.w_in.at[0, 0].set(jnp.nan)

    sess.on_metrics = poison
    sess.train_resilient(health_every=1, skip_poison=True, backoff_s=0.0)
    r = sess.last_report
    assert r.health_failures == 1 and r.batches_skipped == 1
    assert sess.state.batches_seen == n     # counters advanced through skip
    assert _digest(sess.state) != base_digest  # one update excised
    skipped_key = next(iter(sess.poison_skip))

    # reference: same workload with that batch excised from the start
    ref = TrainSession(BatchingPipeline(corpus, cfg, vocab=vocab), cfg,
                       backend="jnp")
    ref.poison_skip.add(skipped_key)
    ref.train()
    assert _digest(sess.state) == _digest(ref.state)


def test_skip_poison_requires_unit_health_probe(workload, tmp_path):
    sess = _session(workload, tmp_path)
    with pytest.raises(ValueError, match="health_every=1"):
        sess.train_resilient(skip_poison=True, health_every=2)


def test_restore_latest_reinit_without_checkpoint(workload, tmp_path):
    """With no usable checkpoint the rollback restarts from the seed —
    and that replay-from-scratch is still bit-exact."""
    cfg, corpus, vocab, base_digest, n = workload
    sess = _session(workload, tmp_path, ckpt_every=0)  # never checkpoints
    sess.train(max_batches=4)
    assert sess.restore_latest() is None
    assert sess.state.batches_seen == 0
    sess.train()
    assert _digest(sess.state) == base_digest


# ------------------------------------------------- checkpoint crash-atomics
def _save_two(d, step_a=2, step_b=4):
    tree = {"w": np.arange(8, dtype=np.float32)}
    ckpt.save(d, step_a, tree, extra={"mark": step_a})
    tree2 = {"w": np.arange(8, dtype=np.float32) * 2}
    ckpt.save(d, step_b, tree2, extra={"mark": step_b})
    return tree, tree2


def test_truncated_arrays_falls_back_and_quarantines(tmp_path):
    d = str(tmp_path / "ck")
    tree, _ = _save_two(d)
    path = os.path.join(d, "step_00000004", "arrays.npz")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    like = {"w": np.zeros(8, dtype=np.float32)}
    got, extra = ckpt.restore(d, like, step=None)
    assert extra["mark"] == 2
    np.testing.assert_array_equal(got["w"], tree["w"])
    assert any(".corrupt" in n for n in os.listdir(d))
    # the quarantined dir is out of the restore path for good
    assert ckpt.latest_step(d) == 2


def test_explicit_step_restore_of_corrupt_raises_after_quarantine(tmp_path):
    d = str(tmp_path / "ck")
    _save_two(d)
    path = os.path.join(d, "step_00000004", "arrays.npz")
    with open(path, "r+b") as f:
        f.truncate(10)
    like = {"w": np.zeros(8, dtype=np.float32)}
    with pytest.raises(ckpt.CorruptCheckpoint):
        ckpt.restore(d, like, step=4)
    assert any(n.startswith("step_00000004.corrupt")
               for n in os.listdir(d))


def test_partial_dir_latest_step_quarantines(tmp_path):
    d = str(tmp_path / "ck")
    _save_two(d)
    os.remove(os.path.join(d, "step_00000004", "arrays.npz"))
    assert ckpt.latest_step(d) == 2
    assert any(".corrupt" in n for n in os.listdir(d))


def _backdate(path, by_s=2 * ckpt.STALE_GRACE_S):
    """Age a dir past the maintenance grace (a crash leftover, not a
    live publisher's in-flight dir)."""
    t = time.time() - by_s
    os.utime(path, (t, t))


def test_clean_stale_recovers_displaced_checkpoint(tmp_path):
    """A crash between displace-rename and publish-rename must not lose
    the checkpoint: the displaced .old dir is renamed back (once it is
    old enough to be a crash leftover rather than a live publish)."""
    d = str(tmp_path / "ck")
    _save_two(d)
    final = os.path.join(d, "step_00000004")
    os.rename(final, final + ".old.deadbeef")   # simulate the crash window
    _backdate(final + ".old.deadbeef")
    assert ckpt.latest_step(d) == 4             # recovered, not lost
    like = {"w": np.zeros(8, dtype=np.float32)}
    _, extra = ckpt.restore(d, like, step=4)
    assert extra["mark"] == 4


def test_fresh_displaced_dir_left_for_live_publisher(tmp_path):
    """A *young* .old dir may belong to a publisher between its two
    renames — a concurrent latest_step must not rename it back (the
    publisher's tmp->final rename would then hit an existing dir)."""
    d = str(tmp_path / "ck")
    _save_two(d)
    final = os.path.join(d, "step_00000004")
    os.rename(final, final + ".old.deadbeef")
    assert ckpt.latest_step(d) == 2             # not recovered (yet)
    assert os.path.isdir(final + ".old.deadbeef")   # and not deleted


def test_stale_tmp_dirs_cleaned_on_save(tmp_path):
    d = str(tmp_path / "ck")
    _save_two(d)
    stale = os.path.join(d, "step_00000006.tmp.abc123")
    os.makedirs(stale)
    _backdate(stale)
    ckpt.save(d, 8, {"w": np.zeros(3, dtype=np.float32)})
    assert not os.path.exists(stale)
    assert not [n for n in os.listdir(d) if ".tmp" in n]


def test_fresh_tmp_dir_survives_concurrent_reader(tmp_path):
    """A young tmp dir is an in-flight publish: a concurrent reader's
    latest_step must neither delete it nor surface it as a step."""
    d = str(tmp_path / "ck")
    _save_two(d)
    inflight = os.path.join(d, "step_00000006.tmp.abc123")
    os.makedirs(inflight)
    assert ckpt.latest_step(d) == 4
    assert os.path.isdir(inflight)


def test_checksum_corruption_detected(tmp_path):
    """Flipped bytes with intact zip structure: the sha1 verify catches it
    and the fallback still lands on the older step."""
    d = str(tmp_path / "ck")
    tree, _ = _save_two(d)
    man_path = os.path.join(d, "step_00000004", "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["leaves"][0]["sha1"] = "0" * 40
    with open(man_path, "w") as f:
        json.dump(man, f)
    like = {"w": np.zeros(8, dtype=np.float32)}
    got, extra = ckpt.restore(d, like, step=None)
    assert extra["mark"] == 2


# --------------------------------------------------- resilience primitives
def test_retry_budget_refills_after_sustained_progress():
    inj = FailureInjector([1, 5, 9, 13])
    calls = []

    def step(i):
        calls.append(i)
        inj.check(i)

    # 4 sparse failures vs a budget of 2: only survivable with refill
    final = run_with_recovery(
        step, start_step=0, end_step=16, on_failure=lambda s, e: s,
        policy=RetryPolicy(max_restarts=2, backoff_s=0.0, reset_after=3))
    assert final == 16

    inj2 = FailureInjector([1, 5, 9, 13])
    with pytest.raises(RuntimeError, match="injected failure"):
        run_with_recovery(
            lambda i: inj2.check(i), start_step=0, end_step=16,
            on_failure=lambda s, e: s,
            policy=RetryPolicy(max_restarts=2, backoff_s=0.0))


def test_run_with_recovery_should_stop_mode():
    seen = []
    final = run_with_recovery(
        seen.append, start_step=0, on_failure=lambda s, e: s,
        should_stop=lambda: len(seen) >= 5)
    assert final == 5 and seen == [0, 1, 2, 3, 4]
    with pytest.raises(ValueError, match="end_step or should_stop"):
        run_with_recovery(lambda i: None, start_step=0,
                          on_failure=lambda s, e: s)


def test_watchdog_timeout_not_swallowed_by_step_exception():
    """A step that both overruns the watchdog AND raises must surface the
    timeout chained from the step's exception — neither fact is lost."""
    with pytest.raises(StepTimeout) as ei:
        with Watchdog(0.01):
            time.sleep(0.1)
            raise ValueError("step also failed")
    assert isinstance(ei.value.__cause__, ValueError)

    # non-Exception escapes win over the timeout and propagate unchanged
    with pytest.raises(KeyboardInterrupt):
        with Watchdog(0.01):
            time.sleep(0.1)
            raise KeyboardInterrupt()


def test_straggler_ema_seeds_then_decays():
    m = StragglerMonitor(decay=0.9)
    m.report("h", 2.0)
    assert m.times["h"] == 2.0          # first report seeds
    m.report("h", 1.0)
    assert m.times["h"] == pytest.approx(0.9 * 2.0 + 0.1 * 1.0)


def test_straggler_window_evicts_departed_hosts():
    m = StragglerMonitor(decay=0.5, threshold=1.4, window=6)
    m.report("gone", 9.0)
    for _ in range(4):
        for h in ("h0", "h1", "h2"):
            m.report(h, 1.0)
    assert "gone" not in m.times        # departed host no longer drags
    assert m.stragglers() == []


# ------------------------------------------------------------ chaos engine
def test_chaos_smoke_schedule_bit_exact():
    from repro.train.chaos import SCHEDULES, run_chaos

    r = run_chaos(SCHEDULES["smoke"])
    assert r["digest_match"] == 1
    assert r["restarts"] == 1
    assert r["faults_fired"] == r["faults_scheduled"] == 1
