"""Window-tile batched kernel (`_kernel_tiled`) vs its jnp oracle
(`batch_sgns_tiled_ref`) and the sequential kernel (DESIGN.md §4)."""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.data.batching import plan_tiles
from repro.kernels.fullw2v import (fullw2v_pallas, fullw2v_pallas_tiled,
                                   fullw2v_pallas_tiled_fused)
from repro.kernels.ref import batch_sgns_ref, batch_sgns_tiled_ref
from tests.conftest import make_distinct_negs


def _make(rng, V, d, S, L, N):
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    return w_in, w_out, tokens, negs


def _run_tiled(w_in, w_out, tokens, negs, lengths, lr, w_f, tile,
               kernel=True):
    plan = plan_tiles(tokens, negs, lengths, tile)
    pa = [jnp.asarray(x) for x in (plan.uniq, plan.scatter,
                                   plan.ucount, plan.strict)]
    args = (jnp.asarray(w_in), jnp.asarray(w_out), jnp.asarray(tokens),
            jnp.asarray(negs), jnp.asarray(lengths), jnp.float32(lr), w_f,
            tile, *pa)
    if kernel:
        return fullw2v_pallas_tiled(*args, interpret=True)
    return batch_sgns_tiled_ref(*args)


def test_t1_bit_identical_to_sequential_kernel(rng):
    """Acceptance criterion: T=1 tiled == sequential kernel, bit for bit,
    under the distinctness invariant."""
    V, d, S, L, N, w_f = 30, 128, 2, 10, 3, 2
    w_in, w_out, tokens, negs = _make(rng, V, d, S, L, N)
    lengths = np.array([L, 6], np.int32)
    a_in, a_out = fullw2v_pallas(
        jnp.asarray(w_in), jnp.asarray(w_out), jnp.asarray(tokens),
        jnp.asarray(negs), jnp.asarray(lengths), jnp.float32(0.05), w_f,
        interpret=True)
    b_in, b_out = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.05,
                             w_f, tile=1)
    assert (np.asarray(a_in) == np.asarray(b_in)).all()
    assert (np.asarray(a_out) == np.asarray(b_out)).all()


def test_tiled_kernel_matches_oracle_t4(rng):
    V, d, S, L, N, w_f = 25, 128, 2, 12, 2, 2
    w_in, w_out, tokens, negs = _make(rng, V, d, S, L, N)
    lengths = np.array([L, 7], np.int32)
    k_in, k_out = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.08,
                             w_f, tile=4)
    r_in, r_out = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.08,
                             w_f, tile=4, kernel=False)
    np.testing.assert_allclose(np.asarray(k_in), np.asarray(r_in),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(k_out), np.asarray(r_out),
                               atol=2e-5, rtol=1e-4)


def test_strict_tiles_fall_back_to_sequential(rng):
    """A batch engineered so every tile has a target-involved collision:
    the tiled result must be identical to the sequential kernel (strict
    path == exact replay)."""
    V, d, L, N, w_f, tile = 120, 128, 8, 2, 2, 4
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = np.arange(L, dtype=np.int32)[None, :]
    # duplicate token at distance r <= 5 < rt: exercises the sequential
    # (r-distance) store schedule — the reload must see the first copy's
    # flushed updates exactly as the sequential kernel does
    tokens[0, 5] = tokens[0, 0]
    negs = np.zeros((1, L, N), np.int32)
    for t in range(L):
        # first negative collides with a *target* of the same tile
        tile_first = tile * (t // tile)
        negs[0, t, 0] = tokens[0, t + 1] if t == tile_first \
            else tokens[0, tile_first]
        negs[0, t, 1] = 100 + t                          # unique filler
    lengths = np.array([L], np.int32)
    plan = plan_tiles(tokens, negs, lengths, tile)
    assert plan.strict.all()
    a = fullw2v_pallas(
        jnp.asarray(w_in), jnp.asarray(w_out), jnp.asarray(tokens),
        jnp.asarray(negs), jnp.asarray(lengths), jnp.float32(0.05), w_f,
        interpret=True)
    b = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.05, w_f, tile)
    assert (np.asarray(a[0]) == np.asarray(b[0])).all()
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()


@given(
    st.integers(5, 25),       # vocab
    st.integers(1, 10),       # max sentence length
    st.integers(1, 2),        # negatives
    st.integers(1, 2),        # w_f
    st.sampled_from([2, 3, 8]),
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=6, deadline=None)
def test_tiled_kernel_matches_oracle_hypothesis(vocab, L, n_neg, w_f, tile,
                                                seed):
    if vocab <= n_neg:
        vocab = n_neg + 2
    rng = np.random.default_rng(seed)
    w_in, w_out, tokens, negs = _make(rng, vocab, 128, 1, L, n_neg)
    lengths = np.array([rng.integers(1, L + 1)], dtype=np.int32)
    k = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.05, w_f, tile)
    r = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.05, w_f, tile,
                   kernel=False)
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]),
                               atol=3e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(k[1]), np.asarray(r[1]),
                               atol=3e-5, rtol=2e-4)


def test_tiled_relaxation_is_small(rng):
    """T>1 collision-free tiles read pre-tile values — the divergence from
    the strictly-ordered kernel must stay O(lr²) small for one batch."""
    V, d, L, N, w_f = 200, 128, 16, 2, 2
    w_in, w_out, tokens, negs = _make(rng, V, d, 1, L, N)
    lengths = np.array([L], np.int32)
    a_in, _ = batch_sgns_ref(
        jnp.asarray(w_in), jnp.asarray(w_out), jnp.asarray(tokens),
        jnp.asarray(negs), jnp.asarray(lengths), jnp.float32(0.05), w_f)
    b_in, _ = _run_tiled(w_in, w_out, tokens, negs, lengths, 0.05, w_f,
                         tile=4, kernel=False)
    diff = np.abs(np.asarray(a_in) - np.asarray(b_in)).max()
    assert diff < 1e-2, diff
    assert np.isfinite(np.asarray(b_in)).all()


def test_fused_split_table_bit_identical_to_concat(rng):
    """DESIGN.md §8 fused gather: the split-table kernel (hot replica +
    gathered cold block, double-buffered cold-row prefetch) must be
    bit-identical to the plain tiled kernel on ``concat(hot, got)`` — on a
    small strict-heavy batch (sequential replay path) and on a larger
    mostly-collision-free batch with the same cold row reused across tiles
    (the prefetch-dedup predicate's hard case)."""
    w_f, tile, N, d = 2, 4, 3, 128
    for V, hot, L in ((30, 7, 10), (600, 17, 16)):
        w_in, w_out, tokens, negs = _make(rng, V, d, 2, L, N)
        if V > 100:
            # same cold working row in two tiles of one sentence, and a
            # token also appearing as another tile's negative
            negs[0, 1, 0] = negs[0, 2 * tile + 1, 0] = hot + 3
            negs[1, tile, 1] = tokens[1, 0]
        lengths = np.array([L, L - 3], np.int32)
        plan = plan_tiles(tokens, negs, lengths, tile)
        pa = [jnp.asarray(x) for x in (plan.uniq, plan.scatter,
                                       plan.ucount, plan.strict)]
        common = (jnp.asarray(tokens), jnp.asarray(negs),
                  jnp.asarray(lengths), jnp.float32(0.05), w_f, tile, *pa)
        r_in, r_out = fullw2v_pallas_tiled(
            jnp.asarray(w_in), jnp.asarray(w_out), *common, interpret=True)
        f = fullw2v_pallas_tiled_fused(
            jnp.asarray(w_in[:hot]), jnp.asarray(w_out[:hot]),
            jnp.asarray(w_in[hot:]), jnp.asarray(w_out[hot:]),
            *common, interpret=True)
        np.testing.assert_array_equal(np.asarray(r_in),
                                      np.concatenate([f[0], f[2]]))
        np.testing.assert_array_equal(np.asarray(r_out),
                                      np.concatenate([f[1], f[3]]))


def test_trainer_tile_windows_end_to_end():
    """cfg.tile_windows threads host plan → ops dispatch → tiled backend."""
    from repro.configs.w2v import smoke
    from repro.core.trainer import W2VTrainer
    from repro.data.batching import BatchingPipeline
    from repro.data.corpus import synthetic_cluster_corpus

    cfg = smoke(tile_windows=4, dim=128)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=40, mean_len=10, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    tr = W2VTrainer(pipe, cfg, backend="jnp")
    st_ = tr.train(epochs=1, max_batches=1)
    assert st_.words_seen > 0
    assert np.isfinite(tr.embeddings()).all()
