"""End-to-end behaviour tests for the paper's system."""
import jax.numpy as jnp
import numpy as np

from repro.configs.w2v import W2VConfig, smoke
from repro.core.baselines import matrix_sgns
from repro.core.quality import evaluate
from repro.core.trainer import W2VTrainer, init_state
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus
from repro.kernels import ops
from repro.kernels.registry import StepInputs


def test_fullw2v_quality_matches_pword2vec_baseline():
    """Paper Table 7: FULL-W2V must match the shared-negative baseline's
    embedding quality (same corpus, same hyperparameters, same epochs)."""
    cfg = smoke(epochs=5, dim=32, sentences_per_batch=64)
    corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                      n_sentences=500, mean_len=12, seed=0)
    inv = None
    scores = {}
    for name in ("fullw2v", "pword2vec"):
        pipe = BatchingPipeline(corpus, cfg)
        if inv is None:
            inv = np.zeros(pipe.vocab.size, dtype=int)
            for w, i in pipe.vocab.ids.items():
                inv[i] = corpus.clusters[w]
        st = init_state(pipe.vocab.size, cfg)
        wi, wo = st.w_in, st.w_out
        words, total = 0, pipe.epoch_words * cfg.epochs
        for _ in range(cfg.epochs):
            for b in pipe.batches(pad_len=48):
                lr = jnp.float32(cfg.lr * max(1 - words / total, 1e-4))
                if name == "fullw2v":
                    wi, wo = ops.sgns_update(wi, wo, b.step_inputs(lr),
                                             cfg, backend="jnp")
                else:
                    wi, wo = matrix_sgns(
                        wi, wo, jnp.asarray(b.tokens), jnp.asarray(b.negs),
                        jnp.asarray(b.lengths), lr, cfg.fixed_window)
                words += b.n_words
        scores[name] = evaluate(np.asarray(wi), inv, seed=0)

    a, b = scores["fullw2v"], scores["pword2vec"]
    assert a["separation"] > 0.15
    # statistical equivalence: within 25% of each other
    assert abs(a["separation"] - b["separation"]) < 0.25 * max(
        a["separation"], b["separation"]), scores


def test_semantic_ordering_strictness():
    """Strict sequential window ordering: permuting sentences changes the
    result (the algorithm is order-dependent by design), while identical
    inputs reproduce bit-identical embeddings."""
    cfg = smoke(epochs=1)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=60, mean_len=10, seed=1)
    pipe = BatchingPipeline(corpus, cfg)
    batch = next(pipe.batches(pad_len=32))
    st = init_state(pipe.vocab.size, cfg)

    def run(tokens, negs, lengths):
        step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                          jnp.asarray(lengths), jnp.float32(0.05))
        return ops.sgns_update(jnp.array(st.w_in), jnp.array(st.w_out),
                               step, cfg, backend="jnp")

    a1, _ = run(batch.tokens, batch.negs, batch.lengths)
    a2, _ = run(batch.tokens, batch.negs, batch.lengths)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    perm = np.random.default_rng(0).permutation(batch.tokens.shape[0])
    b1, _ = run(batch.tokens[perm], batch.negs[perm], batch.lengths[perm])
    assert np.abs(np.asarray(a1) - np.asarray(b1)).max() > 0


def test_fixed_window_is_half_of_w():
    assert W2VConfig(window=5).fixed_window == 3
    assert W2VConfig(window=10).fixed_window == 5
    assert W2VConfig(window=1).fixed_window == 1
