"""End-to-end W2V training behaviour: learning, LR decay, quality."""
import numpy as np
import pytest

from repro.configs.w2v import smoke
from repro.core.quality import evaluate, spearman
from repro.core.trainer import W2VTrainer, init_state
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus


def _setup(epochs=6, dim=32, seed=0):
    cfg = smoke(epochs=epochs, dim=dim)
    corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                      n_sentences=500, mean_len=12,
                                      seed=seed)
    pipe = BatchingPipeline(corpus, cfg)
    inv = np.zeros(pipe.vocab.size, dtype=int)
    for w, i in pipe.vocab.ids.items():
        inv[i] = corpus.clusters[w]
    return cfg, corpus, pipe, inv


def test_training_learns_cluster_structure():
    cfg, corpus, pipe, inv = _setup()
    tr = W2VTrainer(pipe, cfg, backend="jnp")
    tr.train()
    m = evaluate(tr.embeddings(), inv, seed=0)
    assert m["separation"] > 0.2, m
    assert m["nn_purity"] > 0.7, m
    assert m["spearman"] > 0.3, m


def test_lr_decays_linearly():
    cfg, corpus, pipe, inv = _setup(epochs=2)
    tr = W2VTrainer(pipe, cfg, backend="jnp")
    lr0 = tr.current_lr()
    tr.train()
    assert tr.current_lr() < lr0
    assert tr.current_lr() >= cfg.lr * cfg.min_lr_frac - 1e-12


def test_untrained_embeddings_have_no_structure():
    cfg, corpus, pipe, inv = _setup()
    st = init_state(pipe.vocab.size, cfg)
    m = evaluate(np.asarray(st.w_in), inv, seed=0)
    assert abs(m["separation"]) < 0.05


def test_nearest_neighbours_same_cluster():
    cfg, corpus, pipe, inv = _setup(epochs=8)
    tr = W2VTrainer(pipe, cfg, backend="jnp")
    tr.train()
    hits = 0
    for wid in range(0, 30, 3):
        nn = tr.nearest(wid, k=3)
        hits += (inv[nn] == inv[wid]).sum()
    assert hits >= 15  # of 30


def test_spearman_helper():
    a = np.array([1.0, 2.0, 3.0, 4.0])
    assert abs(spearman(a, a * 10) - 1.0) < 1e-9
    assert abs(spearman(a, -a) + 1.0) < 1e-9
