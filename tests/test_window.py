"""Ring-buffer lifetime state machine properties (core/window.py)."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.window import (
    RingBufferSim,
    lifetime,
    loads_and_stores,
    ring_slots,
    schedule,
    slot_of,
    traffic_reduction,
)


@given(st.integers(1, 40), st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_every_position_loaded_and_stored_once(length, w_f):
    loads, stores = loads_and_stores(length, w_f)
    # lifetime reuse: each position touches HBM exactly twice (1 load +
    # 1 store) regardless of how many windows reuse it — the paper's claim
    assert loads == length
    assert stores == length


@given(st.integers(1, 40), st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_residency_invariant(length, w_f):
    """At every window t, all context positions of t are buffer-resident."""
    RingBufferSim(length, w_f).run()


@given(st.integers(1, 60), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_slot_conflict_freedom(length, w_f):
    """Positions p and p+R have disjoint lifetimes, so slot reuse is safe."""
    r = ring_slots(w_f)
    for p in range(length - r):
        _, last = lifetime(p, w_f, length)
        first, _ = lifetime(p + r, w_f, length)
        assert last < first
        assert slot_of(p, w_f) == slot_of(p + r, w_f)


def test_schedule_order_store_before_load():
    evs = schedule(10, 2)
    seen = {}
    for e in evs:
        if e.kind == "load":
            s = slot_of(e.position, 2)
            if s in seen:
                assert seen[s] == "stored", f"slot {s} overwritten unsaved"
            seen[s] = "loaded"
        elif e.kind == "store":
            seen[slot_of(e.position, 2)] = "stored"


def test_traffic_reduction_values():
    # paper §3.2: ~86% for W_f=3, ~91% for W_f=5
    assert abs(traffic_reduction(3) - 6 / 7) < 1e-9
    assert abs(traffic_reduction(5) - 10 / 11) < 1e-9
