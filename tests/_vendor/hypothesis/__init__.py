"""Minimal, deterministic stand-in for the `hypothesis` library.

The container image has no `hypothesis` wheel and installing packages is not
allowed, so `tests/conftest.py` puts this vendored shim on ``sys.path`` when
the real library is absent. It supports exactly the subset the test-suite
uses:

    @given(st.integers(a, b), st.floats(a, b), st.sampled_from(xs))
    @settings(max_examples=N, deadline=None)

Each ``@given`` test runs ``max_examples`` times with values drawn from a
fixed-seed PRNG, after first exhausting the strategies' boundary examples
(min/max for ranges, first/last for ``sampled_from``) — deterministic across
runs, so failures are reproducible without shrinking machinery.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
from typing import Any, Callable

__version__ = "0.0-vendored-shim"

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xF011B2C  # arbitrary fixed seed: runs are deterministic


class _Settings:
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline: Any = None, **_: Any):
        self.max_examples = max_examples
        self.deadline = deadline


def settings(**kwargs: Any) -> Callable:
    """Decorator attaching run settings; pairs with :func:`given`."""
    cfg = _Settings(**kwargs)

    def deco(fn: Callable) -> Callable:
        fn._shim_settings = cfg
        return fn

    return deco


def assume(condition: bool) -> None:
    """Real hypothesis aborts the example; the shim only supports uses where
    rejection is rare, so it just skips via an exception pytest ignores."""
    if not condition:
        raise _Rejected()


class _Rejected(Exception):
    pass


def given(*strategies: "SearchStrategy") -> Callable:
    def deco(fn: Callable) -> Callable:
        cfg = getattr(fn, "_shim_settings", None)

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            n = (cfg or getattr(wrapper, "_shim_settings", None)
                 or _Settings()).max_examples
            rng = random.Random(_SEED)
            boundary = itertools.product(*[s.boundary_examples()
                                           for s in strategies])
            drawn = 0
            for vals in boundary:
                if drawn >= n:
                    break
                _run_one(fn, args, kwargs, vals)
                drawn += 1
            while drawn < n:
                vals = tuple(s.draw(rng) for s in strategies)
                _run_one(fn, args, kwargs, vals)
                drawn += 1

        # tolerate decorator order @settings(...) above @given(...)
        wrapper._shim_given = True
        # hide the strategy parameters from pytest's fixture resolution
        # (real hypothesis does the same: the wrapper takes no arguments)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def _run_one(fn: Callable, args: tuple, kwargs: dict, vals: tuple) -> None:
    try:
        fn(*args, *vals, **kwargs)
    except _Rejected:
        pass
    except Exception as e:  # noqa: BLE001 — re-raise with the failing example
        raise AssertionError(
            f"falsifying example (hypothesis shim): {vals!r}") from e
