"""Strategies for the vendored hypothesis shim (see ``__init__.py``)."""
from __future__ import annotations

import random
from typing import Any, Callable, List, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any],
                 boundary: Sequence[Any] = ()):
        self._draw = draw
        self._boundary = list(boundary)

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def boundary_examples(self) -> List[Any]:
        """Edge cases tried before random sampling (min/max of ranges)."""
        return self._boundary or [self.draw(random.Random(0))]


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value),
        boundary=[min_value] if min_value == max_value
        else [min_value, max_value])


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.uniform(min_value, max_value),
        boundary=[min_value, max_value])


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: rng.choice(elements),
        boundary=[elements[0]] if len(elements) == 1
        else [elements[0], elements[-1]])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5,
                          boundary=[False, True])


def lists(elem: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        return [elem.draw(rng) for _ in range(n)]

    return SearchStrategy(draw, boundary=[[elem.draw(random.Random(0))
                                           for _ in range(min_size)]])
