"""Async host pipeline (DESIGN.md §4.1): worker-count invariance,
bit-identity with the synchronous pipeline, bounded-queue backpressure,
clean shutdown, steady-state stats, and exact mid-epoch resume with
prefetch enabled."""
import time

import numpy as np
import pytest

from repro.configs.w2v import smoke
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_zipf_corpus
from repro.data.prefetch import AsyncBatchingPipeline, make_pipeline


def _corpus(n=600, seed=0):
    return synthetic_zipf_corpus(vocab_size=300, n_sentences=n,
                                 mean_len=12, seed=seed)


def _cfg(**kw):
    base = dict(sentences_per_batch=64, max_sentence_len=32)
    base.update(kw)
    return smoke(**base)


def _same_stream(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert np.array_equal(x.tokens, y.tokens)
        assert np.array_equal(x.negs, y.negs)
        assert np.array_equal(x.lengths, y.lengths)
        assert x.n_words == y.n_words
        assert (x.plan is None) == (y.plan is None)
        if x.plan is not None:
            assert np.array_equal(x.plan.uniq, y.plan.uniq)
            assert np.array_equal(x.plan.scatter, y.plan.scatter)
            assert np.array_equal(x.plan.ucount, y.plan.ucount)
            assert np.array_equal(x.plan.strict, y.plan.strict)
        assert (x.exchange is None) == (y.exchange is None)
        if x.exchange is not None:
            ex, ey = x.exchange, y.exchange
            assert ex.placement == ey.placement
            for f in ("tokens", "negs", "cold_ids", "bucket_ids",
                      "bucket_pos"):
                assert np.array_equal(getattr(ex, f), getattr(ey, f)), f


def test_async_bitwise_equals_sync_any_worker_count():
    cfg = _cfg()
    corpus = _corpus()
    sync = BatchingPipeline(corpus, cfg)
    ref = list(sync.batches(pad_len=32, epoch=0))
    assert len(ref) >= 3
    for workers in (1, 4):
        apipe = AsyncBatchingPipeline(corpus, cfg, vocab=sync.vocab,
                                      workers=workers, depth=3)
        _same_stream(ref, list(apipe.batches(pad_len=32, epoch=0)))


def test_async_tiled_stream_packed_equals_sync():
    """The relaxed modes compose: tile plans + stream packing survive the
    async path bit-for-bit (plan arrays included)."""
    cfg = _cfg(tile_windows=2, ignore_delimiters=True)
    corpus = _corpus()
    sync = BatchingPipeline(corpus, cfg)
    ref = list(sync.batches(pad_len=32, epoch=1))
    assert ref[0].plan is not None
    apipe = AsyncBatchingPipeline(corpus, cfg, vocab=sync.vocab,
                                  workers=3, depth=2)
    _same_stream(ref, list(apipe.batches(pad_len=32, epoch=1)))


@pytest.mark.parametrize("mode", ["thread", "process"])
def test_async_carries_worker_planned_exchange(mode):
    """A placement-aware pipeline attaches the vocab-sharding exchange plan
    (request lists + capacity buckets) in the finalize workers — both
    worker kinds — bit-identically to the synchronous pipeline."""
    from repro.distributed.vocab_placement import VocabPlacement

    cfg = _cfg(vocab_shard=True)
    corpus = _corpus()
    sync = BatchingPipeline(corpus, cfg)
    sync.placement = VocabPlacement.plan(sync.vocab.counts, 2, hot_frac=0.2)
    ref = list(sync.batches(pad_len=32, epoch=0))
    assert ref[0].exchange is not None
    assert ref[0].exchange.bucket_ids is not None
    apipe = AsyncBatchingPipeline(corpus, cfg, vocab=sync.vocab,
                                  workers=2, depth=2, mode=mode)
    apipe.placement = sync.placement
    _same_stream(ref, list(apipe.batches(pad_len=32, epoch=0)))


def test_epochs_draw_distinct_randomness():
    cfg = _cfg()
    pipe = BatchingPipeline(_corpus(), cfg)
    b0 = next(pipe.batches(pad_len=32, epoch=0))
    b1 = next(pipe.batches(pad_len=32, epoch=1))
    b0_again = next(pipe.batches(pad_len=32, epoch=0))
    assert not np.array_equal(b0.negs, b1.negs)
    assert np.array_equal(b0.negs, b0_again.negs)


def test_skip_batches_is_exact_suffix():
    cfg = _cfg()
    corpus = _corpus()
    for pipe in (BatchingPipeline(corpus, cfg),
                 AsyncBatchingPipeline(corpus, cfg, workers=2, depth=2)):
        full = list(pipe.batches(pad_len=32, epoch=3))
        part = list(pipe.batches(pad_len=32, epoch=3, skip_batches=2))
        assert len(part) == len(full) - 2
        _same_stream(full[2:], part)


def test_backpressure_bounds_in_flight_batches():
    cfg = _cfg()
    apipe = AsyncBatchingPipeline(_corpus(1200), cfg, workers=2, depth=2)
    n = 0
    for _ in apipe.batches(pad_len=32, epoch=0):
        time.sleep(0.02)   # slow consumer: producer must hit the bound
        n += 1
    assert n >= 6
    assert 1 <= apipe.prefetch.max_in_flight <= 2
    assert len(apipe.prefetch.depth_samples) == n


def test_worker_exception_propagates_and_shuts_down(monkeypatch):
    import repro.data.prefetch as prefetch_mod

    def boom(packed, cfg, sampler, epoch, placement=None, bag_table=None):
        if packed.index >= 2:
            raise RuntimeError("injected finalize failure")
        return prefetch_mod.finalize_packed.__wrapped__(
            packed, cfg, sampler, epoch, placement, bag_table)

    boom.__wrapped__ = prefetch_mod.finalize_packed
    monkeypatch.setattr(prefetch_mod, "finalize_packed", boom)
    cfg = _cfg()
    apipe = AsyncBatchingPipeline(_corpus(), cfg, workers=2, depth=2)
    with pytest.raises(RuntimeError, match="injected finalize failure"):
        list(apipe.batches(pad_len=32, epoch=0))
    apipe._producer.join(timeout=5.0)
    assert not apipe._producer.is_alive()
    # the pipeline is reusable after a failed epoch
    monkeypatch.setattr(prefetch_mod, "finalize_packed",
                        boom.__wrapped__)
    assert len(list(apipe.batches(pad_len=32, epoch=0))) >= 3


def test_early_close_joins_producer():
    cfg = _cfg()
    apipe = AsyncBatchingPipeline(_corpus(1200), cfg, workers=2, depth=2)
    it = apipe.batches(pad_len=32, epoch=0)
    next(it)
    next(it)
    it.close()
    apipe._producer.join(timeout=5.0)
    assert not apipe._producer.is_alive()


def test_stats_clock_starts_at_first_batch():
    """BatchingStats measures steady-state batching only: pipeline/vocab
    construction and idle time before the first batch never count."""
    cfg = _cfg()
    for pipe in (BatchingPipeline(_corpus(), cfg),
                 AsyncBatchingPipeline(_corpus(), cfg, workers=2, depth=2)):
        time.sleep(0.25)                    # idle after construction
        t0 = time.perf_counter()
        batches = list(pipe.batches(pad_len=32, epoch=0))
        consumed = time.perf_counter() - t0
        assert batches
        assert 0 < pipe.stats.seconds <= consumed + 0.05
        assert pipe.stats.words == sum(b.n_words for b in batches)
        assert np.isfinite(pipe.stats.words_per_sec)


def test_make_pipeline_selects_by_config():
    sync = make_pipeline(_corpus(), _cfg())
    assert type(sync) is BatchingPipeline
    apipe = make_pipeline(_corpus(), _cfg(prefetch_workers=3,
                                          prefetch_depth=5))
    assert isinstance(apipe, AsyncBatchingPipeline)
    assert apipe.workers == 3 and apipe.depth == 5


def test_process_mode_matches_sync(subproc):
    """Process workers (fresh interpreters, no shared state) still emit the
    bit-identical stream. Run in a subprocess with no jax imported so the
    pool fork never races XLA threads."""
    r = subproc("""
        import numpy as np
        from repro.configs.w2v import smoke
        from repro.data.batching import BatchingPipeline
        from repro.data.corpus import synthetic_zipf_corpus
        from repro.data.prefetch import AsyncBatchingPipeline

        cfg = smoke(sentences_per_batch=32, max_sentence_len=32,
                    tile_windows=2)
        corpus = synthetic_zipf_corpus(vocab_size=200, n_sentences=200,
                                       mean_len=12, seed=0)
        sync = BatchingPipeline(corpus, cfg)
        ref = list(sync.batches(pad_len=32, epoch=0))
        apipe = AsyncBatchingPipeline(corpus, cfg, vocab=sync.vocab,
                                      workers=2, depth=2, mode="process")
        got = list(apipe.batches(pad_len=32, epoch=0))
        assert len(ref) == len(got) and len(ref) >= 2
        for a, b in zip(ref, got):
            assert np.array_equal(a.tokens, b.tokens)
            assert np.array_equal(a.negs, b.negs)
            assert np.array_equal(a.plan.uniq, b.plan.uniq)
        print("PROCESS_MODE_OK")
    """)
    assert r.returncode == 0, r.stderr
    assert "PROCESS_MODE_OK" in r.stdout


def test_killed_process_worker_heals_bit_identical(subproc):
    """SIGKILLing a process-pool worker breaks the whole pool
    (BrokenProcessPool): the pipeline must rebuild it and recompute the
    owed batches — the emitted stream stays bit-identical to sync
    (DESIGN.md §9). Run in a subprocess with no jax imported so the pool
    fork never races XLA threads."""
    r = subproc("""
        import os, signal
        import numpy as np
        from repro.configs.w2v import smoke
        from repro.data.batching import BatchingPipeline
        from repro.data.corpus import synthetic_zipf_corpus
        from repro.data.prefetch import AsyncBatchingPipeline

        cfg = smoke(sentences_per_batch=32, max_sentence_len=32)
        corpus = synthetic_zipf_corpus(vocab_size=200, n_sentences=200,
                                       mean_len=12, seed=0)
        sync = BatchingPipeline(corpus, cfg)
        ref = list(sync.batches(pad_len=32, epoch=0))
        assert len(ref) >= 4

        apipe = AsyncBatchingPipeline(corpus, cfg, vocab=sync.vocab,
                                      workers=2, depth=2, mode="process")
        got = []
        for i, b in enumerate(apipe.batches(pad_len=32, epoch=0)):
            got.append(b)
            if i == 0:
                pids = apipe.worker_pids()
                assert pids, "process pool has no live workers"
                os.kill(pids[0], signal.SIGKILL)
        assert apipe.prefetch.heals >= 1, "pool was never healed"
        assert len(got) == len(ref)
        for a, b in zip(ref, got):
            assert np.array_equal(a.tokens, b.tokens)
            assert np.array_equal(a.negs, b.negs)
            assert np.array_equal(a.lengths, b.lengths)
        print("HEAL_OK heals=%d" % apipe.prefetch.heals)
    """)
    assert r.returncode == 0, r.stderr
    assert "HEAL_OK" in r.stdout


def test_dead_producer_surfaces_as_pipeline_fault(monkeypatch):
    """A producer thread that dies without delivering its end-of-epoch
    sentinel must surface as a recoverable PipelineFault within the
    consumer's bounded poll — never a hang."""
    import queue as queue_mod

    import repro.data.prefetch as prefetch_mod

    class SentinelEatingQueue(queue_mod.Queue):
        # drop the end-of-epoch marker: exactly what the consumer sees
        # when the producer is killed between queue puts
        def put(self, item, *a, **kw):
            if isinstance(item, prefetch_mod._EndOfEpoch):
                return
            super().put(item, *a, **kw)

    monkeypatch.setattr(prefetch_mod.queue, "Queue", SentinelEatingQueue)
    cfg = _cfg()
    apipe = AsyncBatchingPipeline(_corpus(), cfg, workers=2, depth=2)
    with pytest.raises(prefetch_mod.PipelineFault, match="producer"):
        list(apipe.batches(pad_len=32, epoch=0))
    apipe._producer.join(timeout=5.0)
    assert not apipe._producer.is_alive()


def test_pipeline_cursor_roundtrip():
    from repro.train.checkpoint import PipelineCursor

    c = PipelineCursor(epoch=2, epoch_batch=7, prefetch_workers=4)
    extra = {"words_seen": 123, **c.to_extra()}
    back = PipelineCursor.from_extra(extra)
    assert back == c
    assert PipelineCursor.from_extra({}) == PipelineCursor()


def test_checkpoint_resume_mid_epoch_with_prefetch(tmp_path):
    """Interrupt mid-epoch, resume with prefetch enabled: final tables are
    bit-identical to the uninterrupted run (keyed randomness + cursor
    fast-forward), and identical to the all-synchronous run."""
    import jax  # noqa: F401  (deferred: keep pipeline tests jax-free)

    from repro.core.trainer import TrainSession

    corpus = _corpus(n=300)
    cfg = _cfg(dim=16, epochs=2, prefetch_workers=2, prefetch_depth=2)
    cfg_sync = _cfg(dim=16, epochs=2)

    def fresh(c):
        return make_pipeline(corpus, c), c

    # uninterrupted, synchronous reference
    pipe, c = fresh(cfg_sync)
    ref = TrainSession(pipe, c, backend="jnp").train()
    ref_in = np.asarray(ref.w_in)

    # uninterrupted with prefetch
    pipe, c = fresh(cfg)
    full = TrainSession(pipe, c, backend="jnp").train()
    assert np.array_equal(ref_in, np.asarray(full.w_in))

    # interrupted mid-epoch + resumed, prefetch on both sides
    ckpt = str(tmp_path / "ckpt")
    pipe, c = fresh(cfg)
    TrainSession(pipe, c, backend="jnp", ckpt_dir=ckpt,
                 ckpt_every=1).train(max_batches=3)
    pipe, c = fresh(cfg)
    resumed = TrainSession(pipe, c, backend="jnp", ckpt_dir=ckpt,
                           ckpt_every=0)
    assert resumed.resumed_step == 3
    resumed.train()
    assert np.array_equal(ref_in, np.asarray(resumed.state.w_in))
