"""Checkpoint directory states a concurrent reader observes while a
publisher is live (DESIGN.md §9/§10): stale tmp dirs, displaced .old
dirs, partially-written and quarantined steps — as seen through
``list_steps`` / ``latest_step`` / ``peek``, the exact calls the serving
snapshot watcher makes against an in-progress ``TrainSupervisor``."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _save(d, step, mark=None):
    ckpt.save(d, step, {"w": np.full(8, step, dtype=np.float32)},
              extra={"mark": mark if mark is not None else step})


def _backdate(path, by_s=2 * ckpt.STALE_GRACE_S):
    t = time.time() - by_s
    os.utime(path, (t, t))


# -- what maintenance-state dirs look like to the read API --------------------
def test_list_steps_ignores_maintenance_dirs(tmp_path):
    d = str(tmp_path)
    _save(d, 2)
    _save(d, 4)
    os.makedirs(os.path.join(d, "step_00000006.tmp.abc"))      # in flight
    os.makedirs(os.path.join(d, "step_00000008.corrupt"))      # quarantined
    os.rename(os.path.join(d, "step_00000002"),
              os.path.join(d, "step_00000002.old.xyz"))        # displaced
    os.makedirs(os.path.join(d, "step_00000010"))              # no manifest
    assert ckpt.list_steps(d) == [4]


def test_latest_step_on_missing_and_empty_dir(tmp_path):
    assert ckpt.latest_step(str(tmp_path / "nope")) is None
    assert ckpt.latest_step(str(tmp_path)) is None


def test_latest_step_with_only_inflight_tmp(tmp_path):
    """Nothing published yet, one publish in flight: the poller sees no
    step and must not disturb the tmp dir."""
    d = str(tmp_path)
    inflight = os.path.join(d, "step_00000002.tmp.abc")
    os.makedirs(inflight)
    assert ckpt.latest_step(d) is None
    assert os.path.isdir(inflight)


def test_peek_skips_newer_inflight_publish(tmp_path):
    """peek(step=None) resolves through latest_step: a newer step still
    being written (tmp dir) is invisible; the finished step is served."""
    d = str(tmp_path)
    _save(d, 2)
    os.makedirs(os.path.join(d, "step_00000004.tmp.abc"))
    leaves, extra = ckpt.peek(d)
    assert extra["mark"] == 2
    assert leaves["w"]["shape"] == (8,)


def test_latest_step_quarantines_partial_missing_arrays(tmp_path):
    d = str(tmp_path)
    _save(d, 2)
    _save(d, 4)
    os.remove(os.path.join(d, "step_00000004", "arrays.npz"))
    assert ckpt.latest_step(d) == 2
    assert any(n.startswith("step_00000004.corrupt")
               for n in os.listdir(d))
    # quarantined steps stay out of every subsequent scan
    assert ckpt.list_steps(d) == [2]
    assert ckpt.latest_step(d) == 2


def test_latest_step_quarantines_unparseable_manifest(tmp_path):
    d = str(tmp_path)
    _save(d, 2)
    _save(d, 4)
    with open(os.path.join(d, "step_00000004", "manifest.json"), "w") as f:
        f.write("{truncated")
    assert ckpt.latest_step(d) == 2
    assert any(".corrupt" in n for n in os.listdir(d))


def test_latest_step_all_steps_partial_returns_none(tmp_path):
    d = str(tmp_path)
    _save(d, 2)
    os.remove(os.path.join(d, "step_00000002", "arrays.npz"))
    assert ckpt.latest_step(d) is None
    with pytest.raises(FileNotFoundError):
        ckpt.peek(d)


def test_peek_reports_split_table_layout(tmp_path):
    """peek surfaces the leaf names + vocab_shard extra the serving index
    switches on — without touching arrays.npz."""
    from repro.distributed.vocab_placement import VocabPlacement
    d = str(tmp_path)
    pl = VocabPlacement(vocab_size=32, hot=8, n_shards=2)
    hot = np.zeros((8, 4), np.float32)
    cold = np.zeros((pl.cold_pad, 4), np.float32)
    ckpt.save(d, 6, {"hot_in": hot, "cold_in": cold,
                     "hot_out": hot, "cold_out": cold},
              extra={"vocab_shard": pl.to_extra()})
    os.remove(os.path.join(d, "step_00000006", "arrays.npz"))
    # arrays gone: restore would fail, but peek still answers from the
    # manifest alone
    leaves, extra = ckpt.peek(d, step=6)
    assert set(leaves) == {"hot_in", "cold_in", "hot_out", "cold_out"}
    assert leaves["cold_in"]["shape"] == (pl.cold_pad, 4)
    assert VocabPlacement.from_extra(extra["vocab_shard"]) == pl


def test_stale_maintenance_dirs_cleaned_after_grace(tmp_path):
    """Crash leftovers older than the grace are swept by the next poll;
    fresh ones (a live publisher's) are left alone."""
    d = str(tmp_path)
    _save(d, 2)
    old_tmp = os.path.join(d, "step_00000004.tmp.dead")
    fresh_tmp = os.path.join(d, "step_00000006.tmp.live")
    os.makedirs(old_tmp)
    os.makedirs(fresh_tmp)
    _backdate(old_tmp)
    assert ckpt.latest_step(d) == 2
    assert not os.path.exists(old_tmp)       # crash leftover swept
    assert os.path.isdir(fresh_tmp)          # in-flight publish untouched


# -- concurrent publisher vs poller -------------------------------------------
def test_concurrent_publisher_and_poller(tmp_path):
    """A publisher saving a stream of checkpoints while a poller hammers
    latest_step/peek/restore: every publish survives (the reader's
    maintenance never deletes an in-flight tmp or recovers a mid-publish
    .old), the poller never crashes, and steps appear in order."""
    d = str(tmp_path)
    n_steps = 30
    errors = []
    seen = []

    def publisher():
        try:
            for s in range(1, n_steps + 1):
                _save(d, s)
        except Exception as e:  # noqa: BLE001
            errors.append(("publisher", e))

    def poller():
        try:
            last = 0
            while last < n_steps and not errors:
                step = ckpt.latest_step(d)
                if step is None:
                    continue
                assert step >= last, f"latest_step went back: {last}->{step}"
                if step != last:
                    seen.append(step)
                    last = step
                # the step latest_step returned must be readable right now
                # (unless the publisher already pruned it: keep=3)
                try:
                    _, extra = ckpt.peek(d, step=step)
                    assert extra["mark"] == step
                except (ckpt.CorruptCheckpoint, FileNotFoundError, OSError):
                    live = ckpt.list_steps(d)
                    assert step not in live, f"step {step} unreadable"
        except Exception as e:  # noqa: BLE001
            errors.append(("poller", e))

    threads = [threading.Thread(target=publisher),
               threading.Thread(target=poller)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    assert not errors, errors
    assert seen and seen[-1] == n_steps
    assert seen == sorted(seen)
    # nothing corrupt was manufactured by the concurrency itself
    assert not [n for n in os.listdir(str(tmp_path)) if ".corrupt" in n]


def test_concurrent_same_step_resave_vs_poller(tmp_path):
    """Same-step re-saves (the supervisor's rollback-then-recheckpoint
    path) displace via .old while a poller reads: the poller must always
    see a readable step and never resurrect the displaced dir."""
    d = str(tmp_path)
    _save(d, 4, mark=0)
    errors = []
    stop = threading.Event()

    def resaver():
        try:
            for i in range(1, 25):
                _save(d, 4, mark=i)
        except Exception as e:  # noqa: BLE001
            errors.append(("resaver", e))
        finally:
            stop.set()

    def poller():
        try:
            while not stop.is_set():
                step = ckpt.latest_step(d)
                assert step in (None, 4)   # mid-displacement: briefly gone
                try:
                    _, extra = ckpt.peek(d, step=4)
                except ckpt.CorruptCheckpoint:
                    continue   # displacement window — retry, like the
                               # snapshot watcher's load-failure path
                assert 0 <= extra["mark"] <= 24
        except Exception as e:  # noqa: BLE001
            errors.append(("poller", e))

    threads = [threading.Thread(target=resaver),
               threading.Thread(target=poller)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    assert not errors, errors
    _, extra = ckpt.peek(d, step=4)
    assert extra["mark"] == 24
    # no .old leftovers old enough to matter, no corrupt dirs
    assert not [n for n in os.listdir(d) if ".corrupt" in n]
