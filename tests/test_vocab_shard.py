"""Vocab-sharded embedding tables (DESIGN.md §8): placement math, exchange
planning, single-device parity with the replicated path, split-table
checkpoints, and engine capability gating. Multi-device parity lives in
``test_multidevice.py`` (subprocess meshes)."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.w2v import smoke
from repro.data.batching import (Batch, BatchingPipeline, first_seen_unique,
                                 plan_tiles)
from repro.data.corpus import synthetic_cluster_corpus
from repro.distributed.vocab_placement import (VocabPlacement, plan_exchange)


# ---------------------------------------------------------------------------
# Placement math
# ---------------------------------------------------------------------------

def test_plan_hot_head_covers_requested_mass():
    counts = np.array([100, 50, 25, 12, 6, 3, 2, 1, 1])  # Zipf-ish, sorted
    pl = VocabPlacement.plan(counts, n_shards=2, coverage=0.9)
    total = counts.sum()
    assert counts[:pl.hot].sum() >= 0.9 * total
    assert pl.hot < counts.size  # and the head is minimal: one less misses
    assert counts[:pl.hot - 1].sum() < 0.9 * total


def test_plan_hot_frac_overrides_coverage():
    counts = np.ones(100, dtype=np.int64)
    pl = VocabPlacement.plan(counts, n_shards=4, hot_frac=0.25)
    assert pl.hot == 25


def test_plan_clamps_to_leave_cold_rows():
    counts = np.array([1000, 1, 1])
    pl = VocabPlacement.plan(counts, n_shards=2, coverage=0.999)
    assert 1 <= pl.hot <= 2   # never the whole vocabulary
    assert pl.cold >= 1
    with pytest.raises(ValueError, match="too small"):
        VocabPlacement.plan(np.array([5]), n_shards=2)


def test_cold_padding_and_per_shard_rows():
    pl = VocabPlacement(vocab_size=103, hot=3, n_shards=4)
    assert pl.cold == 100
    assert pl.cold_pad == 100        # already divisible
    assert pl.cold_per_shard == 25
    assert pl.rows_per_device == 28
    pl2 = VocabPlacement(vocab_size=102, hot=3, n_shards=4)
    assert pl2.cold_pad == 100       # 99 padded up
    # degenerate: fewer cold rows than shards still yields one row/shard
    pl3 = VocabPlacement(vocab_size=4, hot=3, n_shards=4)
    assert pl3.cold_pad == 4 and pl3.cold_per_shard == 1


def test_ownership_is_striped_modulo():
    pl = VocabPlacement(vocab_size=20, hot=4, n_shards=4)
    ids = np.arange(20)
    owner = pl.owner_of(ids)
    assert (owner[:4] == -1).all()                    # hot: no owner
    assert (owner[4:] == (ids[4:] - 4) % 4).all()     # striped
    assert (pl.local_row(ids)[4:] == (ids[4:] - 4) // 4).all()


def test_split_merge_roundtrip_exact(rng):
    pl = VocabPlacement(vocab_size=37, hot=5, n_shards=4)
    full = rng.normal(size=(37, 8)).astype(np.float32)
    hot, cold = pl.split(full)
    assert hot.shape == (5, 8) and cold.shape == (pl.cold_pad, 8)
    np.testing.assert_array_equal(pl.merge(hot, cold), full)
    # shard-major layout: shard i's block holds the ids it owns, in order
    for i in range(4):
        blk = cold[i * pl.cold_per_shard:(i + 1) * pl.cold_per_shard]
        owned = [v for v in range(5, 37) if (v - 5) % 4 == i]
        np.testing.assert_array_equal(blk[:len(owned)], full[owned])


def test_placement_extra_roundtrip():
    pl = VocabPlacement(vocab_size=103, hot=7, n_shards=8)
    assert VocabPlacement.from_extra(pl.to_extra()) == pl


# ---------------------------------------------------------------------------
# Exchange planning
# ---------------------------------------------------------------------------

def test_first_seen_unique_order():
    flat = np.array([7, 3, 7, 9, 3, 1, 9])
    np.testing.assert_array_equal(first_seen_unique(flat), [7, 3, 9, 1])


def _pipeline(tile_windows=1, n_sentences=200):
    cfg = smoke(dim=16, sentences_per_batch=64, tile_windows=tile_windows)
    corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                      n_sentences=n_sentences, mean_len=12,
                                      seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    return cfg, pipe


@pytest.mark.parametrize("tile_windows", [1, 4])
def test_plan_exchange_remap_inverts(tile_windows):
    """Remapped ids, pushed back through the shard's request list, must
    reproduce the original global ids exactly — for tokens, negatives, and
    (T>1) tile-plan rows."""
    cfg, pipe = _pipeline(tile_windows)
    batch = next(pipe.batches(pad_len=cfg.resolved_pad_len))
    n = 4
    pl = VocabPlacement.plan(pipe.vocab.counts, n, hot_frac=0.2)
    ex = plan_exchange(batch, pl)
    per = batch.tokens.shape[0] // n
    for s in range(n):
        sl = slice(s * per, (s + 1) * per)
        # working index w maps back to: w itself (hot prefix) or the
        # shard's w-hot'th requested cold id
        inv = np.concatenate([np.arange(pl.hot, dtype=np.int64),
                              ex.cold_ids[s].astype(np.int64)])
        np.testing.assert_array_equal(inv[ex.tokens[sl]], batch.tokens[sl])
        np.testing.assert_array_equal(inv[ex.negs[sl]], batch.negs[sl])
        if tile_windows > 1:
            np.testing.assert_array_equal(inv[ex.plan_uniq[sl]],
                                          batch.plan.uniq[sl])
        # request list: distinct, all cold, -1 padded suffix
        li = ex.cold_ids[s][ex.cold_ids[s] >= 0]
        assert len(np.unique(li)) == len(li) == ex.n_distinct[s]
        assert (li >= pl.hot).all()
        assert (ex.cold_ids[s][ex.n_distinct[s]:] == -1).all()


def test_plan_exchange_rejects_indivisible_batch():
    cfg, pipe = _pipeline()
    batch = next(pipe.batches(pad_len=cfg.resolved_pad_len))
    pl = VocabPlacement.plan(pipe.vocab.counts, 7)
    with pytest.raises(ValueError, match="multiple of the data axis"):
        plan_exchange(batch, pl)   # 64 sentences, 7 shards


def test_exchange_volume_is_distinct_rows_not_v():
    cfg, pipe = _pipeline()
    batch = next(pipe.batches(pad_len=cfg.resolved_pad_len))
    pl = VocabPlacement.plan(pipe.vocab.counts, 4, hot_frac=0.1)
    ex = plan_exchange(batch, pl)
    d = 16
    assert ex.bytes_exchanged(d) == sum(ex.n_distinct) * d * 4 * 4
    assert sum(ex.n_distinct) <= 4 * pl.cold  # bounded by touched rows


# ---------------------------------------------------------------------------
# Capacity buckets (the request-exact all_to_all schedule)
# ---------------------------------------------------------------------------

def _manual_batch(tokens, negs, tile=0):
    """A hand-built Batch: full-length sentences, optional tile plan."""
    tokens = np.asarray(tokens, dtype=np.int32)
    negs = np.asarray(negs, dtype=np.int32)
    lengths = np.full(tokens.shape[0], tokens.shape[1], dtype=np.int32)
    plan = plan_tiles(tokens, negs, lengths, tile) if tile > 1 else None
    return Batch(tokens=tokens, negs=negs, lengths=lengths,
                 n_words=int(lengths.sum()), plan=plan)


def make_distinct_negs_static(row, hot):
    """Hot negatives distinct from their target (deterministic)."""
    n = 3
    out = np.empty((row.size, n), dtype=np.int32)
    for i, t in enumerate(row):
        pool = [v for v in range(hot) if v != t]
        out[i] = pool[:n]
    return out


def test_plan_exchange_all_hot_batch():
    """A batch touching no cold rows still yields a well-formed (empty)
    plan: minimum-capacity buckets, all slots padding, exact <= dense."""
    pl = VocabPlacement(vocab_size=20, hot=19, n_shards=2)
    tokens = np.tile(np.arange(6), (2, 1))            # ids 0..5: all hot
    negs = np.stack([make_distinct_negs_static(t, 19) for t in tokens])
    batch = _manual_batch(tokens, negs)
    ex = plan_exchange(batch, pl)
    assert ex.n_distinct == [0, 0]
    assert (ex.cold_ids == -1).all()
    np.testing.assert_array_equal(ex.tokens, tokens)  # hot remap = identity
    np.testing.assert_array_equal(ex.negs, negs)
    assert (ex.bucket_ids == -1).all()
    assert ex.bucket_capacity == 8                    # _BUCKET_PAD floor
    assert (ex.bucket_pos == ex.request_width).all()  # every slot drops
    assert ex.bytes_device_exact(16) <= ex.bytes_device_dense(16)


def test_plan_exchange_single_cold_row():
    pl = VocabPlacement(vocab_size=20, hot=10, n_shards=2)
    tokens = np.array([[1, 15, 2, 3], [4, 5, 6, 7]])  # one cold id: 15
    negs = np.stack([make_distinct_negs_static(t, 10) for t in tokens])
    ex = plan_exchange(_manual_batch(tokens, negs), pl)
    assert ex.n_distinct == [1, 0]
    assert ex.cold_ids[0, 0] == 15 and (ex.cold_ids[0, 1:] == -1).all()
    assert ex.tokens[0, 1] == pl.hot  # first request -> working row hot+0
    owner = (15 - pl.hot) % 2
    assert ex.bucket_ids[0, owner, 0] == 15
    assert ex.bucket_pos[0, owner, 0] == 0
    mask = np.ones_like(ex.bucket_ids, dtype=bool)
    mask[0, owner, 0] = False
    assert (ex.bucket_ids[mask] == -1).all()
    assert (ex.bucket_pos[mask] == ex.request_width).all()


def test_duplicate_negatives_across_tiles_request_once():
    """A cold negative repeated in two different window tiles is fused by
    the tile plan AND requested once by the exchange — both plan_uniq
    occurrences remap to the same working row."""
    pl = VocabPlacement(vocab_size=20, hot=10, n_shards=2)
    tokens = np.tile(np.array([1, 2, 3, 4, 5, 6, 7, 8]), (2, 1))
    negs = np.stack([make_distinct_negs_static(t, 10) for t in tokens])
    negs[0, 0, 0] = 17    # tile 0 (windows 0-1)
    negs[0, 5, 0] = 17    # tile 2 (windows 4-5): same cold id, new tile
    batch = _manual_batch(tokens, negs, tile=2)
    assert (batch.plan.uniq[0] == 17).sum() == 2   # once per touching tile
    ex = plan_exchange(batch, pl)
    assert ex.n_distinct[0] == 1
    assert list(ex.cold_ids[0][ex.cold_ids[0] >= 0]) == [17]
    # every remapped occurrence points at the single gathered row
    assert (ex.plan_uniq[0][batch.plan.uniq[0] == 17] == pl.hot).all()
    assert (ex.negs[0][negs[0] == 17] == pl.hot).all()


@given(st.integers(40, 200),        # vocab
       st.sampled_from([1, 2, 4]),  # shards
       st.integers(0, 99))          # seed
@settings(max_examples=12, deadline=None)
def test_bucket_capacity_covers_every_request_list(vocab, n, seed):
    """Property: ownership buckets partition each shard's request list —
    capacities cover the per-owner counts, valid entries are exactly the
    owner's subset in first-seen order, and the position scatter
    reconstructs the request list (the device-side gather's correctness
    precondition)."""
    rng = np.random.default_rng(seed)
    pl = VocabPlacement(vocab_size=vocab, hot=max(vocab // 8, 1), n_shards=n)
    S, L, N = 2 * n, 12, 3
    tokens = rng.integers(0, vocab, size=(S, L)).astype(np.int32)
    negs = rng.integers(0, vocab, size=(S, L, N)).astype(np.int32)
    ex = plan_exchange(_manual_batch(tokens, negs), pl)
    for s in range(n):
        li = ex.cold_ids[s][:ex.n_distinct[s]].astype(np.int64)
        owners = (li - pl.hot) % n
        rebuilt = np.full(ex.request_width + 1, -1, dtype=np.int64)
        positions = []
        for o in range(n):
            ids_so = ex.bucket_ids[s, o]
            valid = ids_so >= 0
            assert valid.sum() == (owners == o).sum() <= ex.bucket_capacity
            # -1 padding is a suffix; entries = owner-o subset, in order
            assert not valid[np.argmin(valid):].any() or valid.all()
            np.testing.assert_array_equal(ids_so[valid], li[owners == o])
            pos = ex.bucket_pos[s, o]
            assert (pos[~valid] == ex.request_width).all()
            rebuilt[pos[valid]] = ids_so[valid]
            positions.extend(pos[valid].tolist())
        # scatter round-trip: gathered rows land in request order
        np.testing.assert_array_equal(rebuilt[:ex.n_distinct[s]], li)
        assert sorted(positions) == list(range(ex.n_distinct[s]))


# ---------------------------------------------------------------------------
# Single-device training parity (the N-device analogue is subprocess-bound
# and lives in test_multidevice.py)
# ---------------------------------------------------------------------------

def _train_pair(tile_windows, max_batches=3):
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline(tile_windows)
    cfg_vs = smoke(dim=16, sentences_per_batch=64,
                   tile_windows=tile_windows, vocab_shard=True,
                   hot_vocab_frac=0.3)
    pipe_vs = BatchingPipeline(pipe.corpus, cfg_vs, vocab=pipe.vocab)
    a = TrainSession(pipe, cfg, backend="jnp")
    b = TrainSession(pipe_vs, cfg_vs, backend="jnp")
    a.train(max_batches=max_batches)
    b.train(max_batches=max_batches)
    return a, b


@pytest.mark.parametrize("tile_windows", [1, 4])
def test_single_device_sharded_training_bit_identical(tile_windows):
    """On one (simulated) shard the vocab-sharded session — gather, compact
    working table, kernel, write-back — must be *bit-identical* to the
    plain replicated session (DESIGN.md §8 parity contract)."""
    a, b = _train_pair(tile_windows)
    assert b.placement is not None and b.placement.n_shards == 1
    np.testing.assert_array_equal(a.embeddings(), b.embeddings())
    # output table too (merge the split state)
    full_out = b.placement.merge(np.asarray(b.state.w_out),
                                 np.asarray(b.state.cold_out))
    np.testing.assert_array_equal(np.asarray(a.state.w_out), full_out)


def test_exchange_dense_and_exact_bit_identical():
    """The request-exact bucketed all_to_all and the dense all_gather +
    psum_scatter exchange are two schedules of the same math: final split
    tables must match bit-for-bit (DESIGN.md §8 exchange contract)."""
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline()
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True,
                   hot_vocab_frac=0.3)
    sessions = []
    for flavor in ("dense", "exact"):
        s = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                          vocab=pipe.vocab),
                         cfg_vs, backend="jnp", exchange=flavor)
        s.train(max_batches=3)
        sessions.append(s)
    a, b = sessions
    np.testing.assert_array_equal(a.embeddings(), b.embeddings())
    np.testing.assert_array_equal(np.asarray(a.state.cold_out),
                                  np.asarray(b.state.cold_out))


def test_sharded_session_reports_split_param_tree():
    _, b = _train_pair(1, max_batches=1)
    params = b.state.params()
    assert set(params) == {"hot_in", "hot_out", "cold_in", "cold_out"}
    assert params["hot_in"].shape[0] == b.placement.hot
    assert params["cold_in"].shape[0] == b.placement.cold_pad


# ---------------------------------------------------------------------------
# Split-table checkpoints: same-format and cross-format restores
# ---------------------------------------------------------------------------

def test_checkpoint_sharded_roundtrip(tmp_path):
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline()
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True,
                   hot_vocab_frac=0.3, epochs=2)
    d = str(tmp_path / "ckpt")
    s1 = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                       vocab=pipe.vocab),
                      cfg_vs, backend="jnp", ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=4)
    s2 = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                       vocab=pipe.vocab),
                      cfg_vs, backend="jnp", ckpt_dir=d)
    assert s2.resumed_step == 4
    np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())
    s2.train(max_batches=1)
    assert s2.state.batches_seen == 5


def test_checkpoint_sharded_restores_into_replicated_session(tmp_path):
    """A split-table checkpoint written by a vocab-sharded run must restore
    into a plain replicated session with identical embeddings (the
    demote-to-one-box escape hatch)."""
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline()
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True,
                   hot_vocab_frac=0.3, epochs=2)
    d = str(tmp_path / "ckpt")
    s1 = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                       vocab=pipe.vocab),
                      cfg_vs, backend="jnp", ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    cfg_rep = smoke(dim=16, sentences_per_batch=64, epochs=2)
    s2 = TrainSession(BatchingPipeline(pipe.corpus, cfg_rep,
                                       vocab=pipe.vocab),
                      cfg_rep, backend="jnp", ckpt_dir=d)
    assert s2.resumed_step == 2 and s2.placement is None
    np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())
    s2.train(max_batches=1)   # and keeps training as a replicated session
    assert s2.state.batches_seen == 3


def test_checkpoint_replicated_restores_into_sharded_session(tmp_path):
    """The promotion direction: a replicated checkpoint resumes into a
    vocab-sharded session (split on load)."""
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline()
    d = str(tmp_path / "ckpt")
    s1 = TrainSession(BatchingPipeline(pipe.corpus, cfg, vocab=pipe.vocab),
                      cfg, backend="jnp", ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True,
                   hot_vocab_frac=0.3)
    s2 = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                       vocab=pipe.vocab),
                      cfg_vs, backend="jnp", ckpt_dir=d)
    assert s2.resumed_step == 2 and s2.placement is not None
    np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())


def test_checkpoint_cross_format_rejects_vocab_mismatch(tmp_path):
    """The cross-format restore path must still reject a checkpoint whose
    tables don't fit this session's vocabulary (restore() through the
    checkpoint's own shapes would otherwise skip that check and training
    would silently clamp out-of-range rows)."""
    from repro.core.trainer import TrainSession
    cfg, pipe = _pipeline()
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True,
                   hot_vocab_frac=0.3, epochs=2)
    d = str(tmp_path / "ckpt")
    s1 = TrainSession(BatchingPipeline(pipe.corpus, cfg_vs,
                                       vocab=pipe.vocab),
                      cfg_vs, backend="jnp", ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    bigger = synthetic_cluster_corpus(n_clusters=10, words_per_cluster=30,
                                      n_sentences=300, mean_len=12, seed=1)
    cfg_rep = smoke(dim=16, sentences_per_batch=64)
    big_pipe = BatchingPipeline(bigger, cfg_rep)
    assert big_pipe.vocab.size != pipe.vocab.size
    with pytest.raises(ValueError, match="vocabulary or dim mismatch"):
        TrainSession(big_pipe, cfg_rep, backend="jnp", ckpt_dir=d)


# ---------------------------------------------------------------------------
# Engine gating
# ---------------------------------------------------------------------------

def test_sgns_update_rejects_vocab_sharded_step(rng):
    import jax.numpy as jnp

    from repro.configs.w2v import W2VConfig
    from repro.kernels import ops
    from repro.kernels.registry import StepInputs
    from tests.conftest import make_distinct_negs
    tokens = rng.integers(0, 20, size=(2, 8)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, 20, 3)
    step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                      jnp.asarray(np.array([8, 8], np.int32)),
                      jnp.float32(0.05),
                      cold_ids=jnp.zeros((1, 8), jnp.int32))
    with pytest.raises(ValueError, match="mesh TrainSession"):
        ops.sgns_update(jnp.zeros((20, 16)), jnp.zeros((20, 16)), step,
                        W2VConfig(dim=16, window=3))


def test_session_rejects_vocab_shard_incapable_backend():
    from repro.core.trainer import TrainSession
    cfg_vs = smoke(dim=16, sentences_per_batch=64, vocab_shard=True)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=100, mean_len=10, seed=0)
    pipe = BatchingPipeline(corpus, cfg_vs)
    with pytest.raises(ValueError, match="vocab-sharded"):
        TrainSession(pipe, cfg_vs, backend="pallas_pipelined")
