"""Sharding rule resolution + param spec validity for every arch."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_arch, list_archs
from repro.distributed.sharding import Rules, param_shardings
from repro.models import lm


def _mesh(multi_pod=False):
    # jax 0.4.37 spells AbstractMesh as a tuple of (name, size) pairs
    if multi_pod:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


def test_resolve_divisibility():
    r = Rules(_mesh())
    assert r.resolve("heads", 64) == "model"
    assert r.resolve("heads", 24) == "model"      # uneven OK (padded)
    assert r.resolve("heads", 24, allow_uneven=False) is None
    assert r.resolve("kv_heads", 2) is None       # kv: replicate if uneven
    assert r.resolve("kv_heads", 16) == "model"
    assert r.resolve("batch", 256) == ("data",)
    assert r.resolve("experts", 128) == "model"


def test_resolve_multipod_batch():
    r = Rules(_mesh(multi_pod=True))
    assert r.resolve("batch", 256) == ("pod", "data")
    # batch=1 (long-context) cannot shard
    assert r.resolve("batch", 1) is None


def test_spec_no_duplicate_axes():
    r = Rules(_mesh())
    spec = r.spec(("vocab", "ff"), (4096, 4096))
    # 'model' may appear only once
    flat = [a for a in spec if a is not None]
    assert len(flat) == 1


def test_pod_axis_dropped_on_single_pod():
    r = Rules(_mesh())
    assert r._present(("pod", "data")) == ("data",)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_shardings_all_archs(arch, multi_pod):
    """Every param leaf of every arch gets an evenly-divisible spec on both
    production meshes (pjit argument requirement)."""
    cfg = get_arch(arch)
    rules = Rules(_mesh(multi_pod))
    p_abs = lm.abstract_params(cfg)
    shards = param_shardings(p_abs, rules)
    flat = jax.tree_util.tree_flatten_with_path(p_abs)[0]
    shard_flat = jax.tree.leaves(
        shards, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat) == len(shard_flat)
    for (path, leaf), sh in zip(flat, shard_flat):
        spec = sh.spec
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in axes:
                size *= dict(zip(rules.mesh.axis_names,
                                 rules.mesh.axis_sizes))[a]
            assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_opt_role_shards_embed():
    cfg = get_arch("qwen3-8b")
    rules = Rules(_mesh())
    p_abs = lm.abstract_params(cfg)
    p_sh = param_shardings(p_abs, rules)
    o_sh = param_shardings(p_abs, rules, role="opt")
    assert p_sh["embed"].spec == P(None, None)           # replicated param
    assert o_sh["embed"].spec != P(None, None)           # ZeRO-sharded state
