"""Engine API: backend registry, capability resolution, sgns_update
dispatch, and the streaming TrainSession lifecycle."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.w2v import W2VConfig, smoke
from repro.data.batching import BatchingPipeline, plan_tiles
from repro.data.corpus import synthetic_cluster_corpus
from repro.kernels import ops, registry
from repro.kernels.ref import batch_sgns_ref
from repro.kernels.registry import StepInputs

ALL_BACKENDS = ("jnp", "pallas", "pallas_pipelined", "pallas_interpret",
                "jnp_tiled", "pallas_tiled", "pallas_tiled_interpret")


# ---------------------------------------------------------------------------
# Registry + resolution
# ---------------------------------------------------------------------------

def test_registry_lists_every_backend():
    names = registry.names()
    for n in ALL_BACKENDS:
        assert n in names, names


def test_cli_choices_cover_registry():
    choices = registry.cli_choices()
    assert choices[0] == "auto"
    for n in ALL_BACKENDS:
        assert n in choices


def test_unknown_backend_raises_actionable():
    with pytest.raises(ValueError, match="registered backends"):
        registry.resolve("cuda")
    with pytest.raises(ValueError, match="registered backends"):
        registry.get("nope")


def test_auto_resolution_cpu():
    assert registry.resolve("auto", tiled=False, platform="cpu").name == "jnp"
    assert (registry.resolve("auto", tiled=True, platform="cpu").name
            == "jnp_tiled")


def test_auto_resolution_tpu():
    assert (registry.resolve("auto", tiled=False, platform="tpu").name
            == "pallas_pipelined")
    assert (registry.resolve("auto", tiled=True, platform="tpu").name
            == "pallas_tiled")


def test_tpu_only_backend_off_tpu_raises_with_escape_hatch():
    for name in ("pallas", "pallas_pipelined", "pallas_tiled"):
        with pytest.raises(ValueError, match="only on TPU") as ei:
            registry.resolve(name, tiled=registry.get(name).needs_plan,
                             platform="cpu")
        assert "interpret" in str(ei.value)  # names the usable fallback


def test_tiled_backend_without_plan_raises():
    with pytest.raises(ValueError, match="tile schedule"):
        registry.resolve("jnp_tiled", tiled=False, platform="cpu")


def test_sequential_names_map_to_tiled_variants():
    assert (registry.resolve("jnp", tiled=True, platform="cpu").name
            == "jnp_tiled")
    assert (registry.resolve("pallas_interpret", tiled=True,
                             platform="cpu").name
            == "pallas_tiled_interpret")
    assert (registry.resolve("pallas", tiled=True, platform="tpu").name
            == "pallas_tiled")


def test_pipelined_to_tiled_mapping_warns_not_silent():
    """The old _TILED_BACKEND map silently downgraded pallas_pipelined to
    the non-prefetching tiled kernel; the resolver must say so."""
    with pytest.warns(UserWarning, match="prefetch"):
        be = registry.resolve("pallas_pipelined", tiled=True, platform="tpu")
    assert be.name == "pallas_tiled"


def test_tiled_resolution_is_idempotent_and_quiet():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert (registry.resolve("jnp_tiled", tiled=True,
                                 platform="cpu").name == "jnp_tiled")


def test_descriptors_declare_consistent_capabilities():
    for name in registry.names():
        be = registry.get(name)
        if be.needs_plan:
            assert be.tiled_variant is None  # tiled forms are terminal
        if be.tiled_variant is not None:
            tv = registry.get(be.tiled_variant)
            assert tv.needs_plan, (name, be.tiled_variant)
        if be.requires_tpu and be.interpret_variant is not None:
            assert not registry.get(be.interpret_variant).requires_tpu
        if be.supports_vocab_shard and be.tiled_variant is not None:
            # T>1 dispatch under a vocab-sharded session must stay capable
            assert registry.get(be.tiled_variant).supports_vocab_shard


def test_double_register_raises():
    from repro.kernels.registry import KernelBackend, register
    registry.names()   # force registration
    with pytest.raises(ValueError, match="already registered"):
        register(KernelBackend(name="jnp", update=lambda *a: a,
                               description="dup"))


def test_vocab_shard_capability_gating():
    """resolve(vocab_shard=True) must reject incapable backends with the
    capable set spelled out, pass capable ones through, and steer 'auto'
    on TPU to the plain (non-pipelined) Pallas kernel."""
    with pytest.raises(ValueError, match="supports_vocab_shard|vocab-sh") \
            as ei:
        registry.resolve("pallas_pipelined", vocab_shard=True,
                         platform="tpu")
    assert "jnp" in str(ei.value)   # names capable alternatives
    assert registry.resolve("jnp", vocab_shard=True,
                            platform="cpu").name == "jnp"
    assert registry.resolve("jnp", tiled=True, vocab_shard=True,
                            platform="cpu").name == "jnp_tiled"
    assert registry.resolve("auto", vocab_shard=True,
                            platform="tpu").name == "pallas"
    assert registry.resolve("auto", vocab_shard=False,
                            platform="tpu").name == "pallas_pipelined"


# ---------------------------------------------------------------------------
# sgns_update dispatch
# ---------------------------------------------------------------------------

def _toy_batch(rng, V=30, d=128, S=2, L=10, N=3):
    from tests.conftest import make_distinct_negs
    w_in = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    w_out = rng.normal(size=(V, d)).astype(np.float32) * 0.1
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = np.array([L, L - 3], np.int32)[:S]
    return w_in, w_out, tokens, negs, lengths


def test_sgns_update_sequential_matches_oracle(rng):
    w_in, w_out, tokens, negs, lengths = _toy_batch(rng)
    cfg = W2VConfig(dim=128, window=3)  # w_f = 2
    step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                      jnp.asarray(lengths), jnp.float32(0.05))
    a_in, a_out = ops.sgns_update(jnp.asarray(w_in), jnp.asarray(w_out),
                                  step, cfg, backend="jnp")
    b_in, b_out = batch_sgns_ref(jnp.asarray(w_in), jnp.asarray(w_out),
                                 jnp.asarray(tokens), jnp.asarray(negs),
                                 jnp.asarray(lengths), jnp.float32(0.05),
                                 cfg.fixed_window)
    np.testing.assert_array_equal(np.asarray(a_in), np.asarray(b_in))
    np.testing.assert_array_equal(np.asarray(a_out), np.asarray(b_out))


def test_sgns_update_with_plan_dispatches_tiled(rng):
    """A StepInputs carrying a plan selects the tiled family; tile size is
    derived from the plan shape, and T=1 stays bit-identical to the
    sequential path (the DESIGN.md §4 invariant through the new API)."""
    w_in, w_out, tokens, negs, lengths = _toy_batch(rng)
    cfg = W2VConfig(dim=128, window=3)
    lr = jnp.float32(0.05)
    plan = plan_tiles(tokens, negs, lengths, 1)
    step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                      jnp.asarray(lengths), lr,
                      jnp.asarray(plan.uniq), jnp.asarray(plan.scatter),
                      jnp.asarray(plan.ucount), jnp.asarray(plan.strict))
    assert step.has_plan and step.tile == 1
    a_in, a_out = ops.sgns_update(jnp.asarray(w_in), jnp.asarray(w_out),
                                  step, cfg, backend="jnp")
    seq = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                     jnp.asarray(lengths), lr)
    b_in, b_out = ops.sgns_update(jnp.asarray(w_in), jnp.asarray(w_out),
                                  seq, cfg, backend="jnp")
    np.testing.assert_array_equal(np.asarray(a_in), np.asarray(b_in))
    np.testing.assert_array_equal(np.asarray(a_out), np.asarray(b_out))


def test_sgns_update_rejects_tiled_backend_without_plan(rng):
    w_in, w_out, tokens, negs, lengths = _toy_batch(rng)
    cfg = W2VConfig(dim=128, window=3)
    step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                      jnp.asarray(lengths), jnp.float32(0.05))
    with pytest.raises(ValueError, match="tile schedule"):
        ops.sgns_update(jnp.asarray(w_in), jnp.asarray(w_out), step, cfg,
                        backend="jnp_tiled")


# ---------------------------------------------------------------------------
# TrainSession lifecycle: pad_len, streaming, checkpoint/resume
# ---------------------------------------------------------------------------

def _session_fixture(tmp_path=None, **cfg_kw):
    from repro.core.trainer import TrainSession
    cfg = smoke(epochs=2, dim=32, sentences_per_batch=64, **cfg_kw)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=300, mean_len=10, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    return TrainSession, cfg, pipe


def test_config_pad_len_default_and_override():
    assert smoke().resolved_pad_len == min(smoke().max_sentence_len, 1024)
    assert W2VConfig(max_sentence_len=4000).resolved_pad_len == 1024
    assert W2VConfig(pad_len=96).resolved_pad_len == 96


def test_session_respects_cfg_pad_len():
    """The session's padded batch length flows from cfg.pad_len, not a
    hardcoded mid-loop cap."""
    TrainSession, cfg, pipe = _session_fixture(pad_len=24)
    recorded = {}
    orig = pipe.batches

    def spy(pad_len=None, **kw):
        recorded["pad_len"] = pad_len
        return orig(pad_len=pad_len, **kw)

    pipe.batches = spy
    sess = TrainSession(pipe, cfg, backend="jnp")
    m = next(iter(sess.stream(max_batches=1)))
    assert m.batches_seen == 1
    assert recorded["pad_len"] == 24


def test_session_invalid_backend_fails_at_construction():
    TrainSession, cfg, pipe = _session_fixture()
    with pytest.raises(ValueError, match="registered backends"):
        TrainSession(pipe, cfg, backend="bogus")


def test_session_stream_yields_metrics():
    TrainSession, cfg, pipe = _session_fixture()
    sess = TrainSession(pipe, cfg, backend="jnp")
    got = list(sess.stream(max_batches=3))
    assert [m.batches_seen for m in got] == [1, 2, 3]
    assert all(m.backend == "jnp" for m in got)
    assert got[-1].words_seen == sess.state.words_seen
    assert got[0].lr >= got[-1].lr  # linear decay


def test_session_mid_epoch_resume_does_not_double_train(tmp_path):
    """A mid-epoch checkpoint resumes past the epoch's already-trained
    batches: total batches/words over crash+resume equal an uninterrupted
    run's, so the LR schedule is never overrun."""
    TrainSession, cfg, pipe = _session_fixture()
    full = TrainSession(BatchingPipeline(pipe.corpus, cfg, vocab=pipe.vocab),
                        cfg, backend="jnp")
    full.train()  # uninterrupted reference
    assert full.current_lr() >= cfg.lr * cfg.min_lr_frac - 1e-12

    d = str(tmp_path / "ckpt")
    s1 = TrainSession(BatchingPipeline(pipe.corpus, cfg, vocab=pipe.vocab),
                      cfg, backend="jnp", ckpt_dir=d, ckpt_every=3)
    s1.train(max_batches=8)  # "crash" mid-epoch-1 (5 batches per epoch)
    assert s1.state.epoch == 1 and s1.state.epoch_batch == 3

    # latest checkpoint is batch 6 (ckpt_every=3): epoch 1, 1 batch in
    s2 = TrainSession(BatchingPipeline(pipe.corpus, cfg, vocab=pipe.vocab),
                      cfg, backend="jnp", ckpt_dir=d)
    assert s2.resumed_step == 6
    assert s2.state.epoch == 1 and s2.state.epoch_batch == 1
    s2.train()
    assert s2.state.batches_seen == full.state.batches_seen
    assert s2.state.words_seen <= full.state.words_seen * 1.05


def test_session_checkpoint_resume_roundtrip(tmp_path):
    TrainSession, cfg, pipe = _session_fixture()
    d = str(tmp_path / "ckpt")
    s1 = TrainSession(pipe, cfg, backend="jnp", ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=4)
    assert s1.resumed_step is None

    pipe2 = BatchingPipeline(pipe.corpus, cfg, vocab=pipe.vocab)
    s2 = TrainSession(pipe2, cfg, backend="jnp", ckpt_dir=d)
    assert s2.resumed_step == 4
    assert s2.state.batches_seen == 4
    assert s2.state.words_seen == s1.state.words_seen
    np.testing.assert_array_equal(np.asarray(s2.state.w_in),
                                  np.asarray(s1.state.w_in))
    # and training continues from there
    s2.train(max_batches=1)
    assert s2.state.batches_seen == 5
