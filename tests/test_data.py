"""Data pipeline: vocab, subsampling, negative sampling, batching."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.w2v import W2VConfig, smoke
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus, synthetic_zipf_corpus
from repro.data.negatives import AliasTable, NegativeSampler
from repro.data.vocab import Vocab


def test_vocab_min_count():
    sents = [["a", "a", "a", "b", "b", "c"]] * 2
    v = Vocab.build(sents, min_count=3)
    assert set(v.ids) == {"a", "b"}
    assert v.counts[v.ids["a"]] == 6
    assert v.total == 10


def test_vocab_encode_drops_oov():
    v = Vocab.build([["x", "x", "y"]], min_count=2)
    assert v.encode(["x", "y", "z", "x"]) == [v.ids["x"], v.ids["x"]]


def test_encode_ids_matches_encode_on_weird_tokens():
    """The vectorized LUT encoder drops exactly what the scalar path drops:
    OOV, negative ints (padding sentinels), out-of-range ints, and — via
    the scalar fallback — mixed-type sentences."""
    v = Vocab.build([[0, 1, 2, 3] * 2], min_count=2)
    for sent in ([1, -1, 2], [3, 10_000, 0], [], [-5, -1],
                 [1.5, 2.0], [1, "x", 2], list(range(8)) * 3):
        assert v.encode_ids(sent).tolist() == v.encode(sent), sent


def test_encode_ids_string_vocab_memoizes_fallback():
    v = Vocab.build([["a", "b", "a", "b"]], min_count=1)
    assert v.encode_ids(["a", "z", "b"]).tolist() == v.encode(["a", "z", "b"])
    # the not-LUT-able verdict is cached: no per-sentence O(V) re-scan
    assert v._lut_checked and v._lut is None


@given(st.floats(1e-6, 1e-2))
@settings(max_examples=20, deadline=None)
def test_keep_probs_bounded(t):
    v = Vocab.build([["a"] * 100, ["b"] * 10], min_count=1)
    p = v.keep_probs(t)
    assert ((p >= 0) & (p <= 1)).all()
    # more frequent words have lower keep probability
    assert p[v.ids["a"]] <= p[v.ids["b"]]


def test_alias_table_distribution(rng):
    w = np.array([1.0, 2.0, 4.0, 8.0])
    t = AliasTable(w)
    draws = t.sample(200_000, rng)
    freq = np.bincount(draws, minlength=4) / len(draws)
    np.testing.assert_allclose(freq, w / w.sum(), atol=0.01)


def test_negative_sampler_distinctness(rng):
    weights = np.ones(20)
    sampler = NegativeSampler(weights, seed=0)
    targets = rng.integers(0, 20, size=(8, 16)).astype(np.int32)
    negs = sampler.sample_batch(targets, 5)
    assert negs.shape == (8, 16, 5)
    # no negative equals its window's target
    assert not (negs == targets[:, :, None]).any()
    # within-window distinctness
    for s in range(8):
        for t in range(16):
            assert len(set(negs[s, t].tolist())) == 5


def test_negative_sampler_tiny_vocab_fallback(rng):
    """vocab barely larger than N forces the deterministic fallback."""
    sampler = NegativeSampler(np.ones(5), seed=0)
    targets = np.zeros((2, 4), np.int32)
    negs = sampler.sample_batch(targets, 4)
    for s in range(2):
        for t in range(4):
            win = negs[s, t].tolist()
            assert 0 not in win and len(set(win)) == 4


def test_batching_shapes_and_padding():
    cfg = smoke(sentences_per_batch=8, max_sentence_len=16)
    corpus = synthetic_zipf_corpus(vocab_size=100, n_sentences=20,
                                   mean_len=10, seed=1)
    pipe = BatchingPipeline(corpus, cfg)
    batches = list(pipe.batches(pad_len=16))
    assert all(b.tokens.shape == (8, 16) for b in batches)
    assert all(b.negs.shape == (8, 16, cfg.negatives) for b in batches)
    for b in batches:
        for i, ln in enumerate(b.lengths):
            if ln:
                assert (b.tokens[i, ln:] == 0).all()
    total = sum(b.n_words for b in batches)
    assert 0 < total <= corpus.n_words


def test_stream_packing_mode():
    cfg = smoke(sentences_per_batch=4, max_sentence_len=32)
    cfg = W2VConfig(**{**cfg.__dict__, "ignore_delimiters": True})
    corpus = synthetic_zipf_corpus(vocab_size=50, n_sentences=30,
                                   mean_len=8, seed=2)
    pipe = BatchingPipeline(corpus, cfg)
    batches = list(pipe.batches())
    # stream packing produces (mostly) full-length pseudo-sentences
    full = [ln for b in batches for ln in b.lengths if ln > 0]
    assert max(full) == 32
    assert sum(1 for x in full if x == 32) >= len(full) - 1


def test_batching_speed_counter():
    cfg = smoke(sentences_per_batch=16)
    corpus = synthetic_zipf_corpus(vocab_size=200, n_sentences=64, seed=3)
    pipe = BatchingPipeline(corpus, cfg)
    list(pipe.batches(pad_len=32))
    assert pipe.stats.words > 0
    assert pipe.stats.words_per_sec > 0


def test_cluster_corpus_structure():
    c = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                 n_sentences=50, seed=0)
    assert c.vocab_size == 32
    assert c.clusters.shape == (32,)
    # sentences dominated by one cluster
    hits = 0
    for s in c.sentences[:20]:
        cl = c.clusters[np.asarray(s)]
        if np.bincount(cl, minlength=4).max() >= len(s) * 0.6:
            hits += 1
    assert hits >= 10
