"""Mixed-precision tables (DESIGN.md §11): quantization codecs, the
``TableSpec`` surface, registry dtype gating, the unified ``ops.step``
entry point (+ deprecated shims), bit-determinism of keyed stochastic
rounding, and f32↔mixed checkpoint restores. Multi-shard restores run in
subprocesses (jax locks the device count at init), exactly like
``test_multidevice.py``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.w2v import smoke
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus
from repro.kernels import ops, quant
from repro.kernels import tables as tables_mod
from repro.kernels.registry import StepInputs, resolve
from repro.kernels.tables import Tables, TableSpec


# ---------------------------------------------------------------------------
# Quantization codecs
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound(rng):
    """Nearest int8 encode/decode error is bounded by half an ulp of the
    per-row scale — the §11 storage-precision contract."""
    x = jnp.asarray(rng.normal(size=(64, 32)) * rng.uniform(
        0.01, 10.0, size=(64, 1)), jnp.float32)   # wildly varying row scales
    q, scale = quant.int8_nearest(x)
    err = np.abs(np.asarray(quant.int8_decode(q, scale)) - np.asarray(x))
    assert np.all(err <= np.asarray(scale)[:, None] * 0.5 + 1e-7)
    # per-row scales: each row's bound tracks its own magnitude
    np.testing.assert_allclose(
        np.asarray(scale), np.abs(np.asarray(x)).max(axis=-1) / 127.0,
        rtol=1e-6)


def test_int8_untouched_row_is_fixed_point(rng):
    """decode→re-encode of an untouched row must be the identity (the
    absmax element encodes exactly ±127), so quantized rows don't drift
    between touches."""
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    q, scale = quant.int8_nearest(x)
    q2, scale2 = quant.int8_nearest(quant.int8_decode(q, scale))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(scale2))


def test_int8_all_zero_row_decodes_to_zero():
    q, scale = quant.int8_nearest(jnp.zeros((3, 4), jnp.float32))
    assert np.all(np.asarray(scale) == 1.0)      # no div-by-zero sentinel
    np.testing.assert_array_equal(
        np.asarray(quant.int8_decode(q, scale)), np.zeros((3, 4)))


def test_int8_stochastic_unbiased_over_keyed_draws():
    """E[decode(stochastic_encode(x))] = x: averaging many keyed draws of
    one row converges to the f32 value (the property that keeps the mixed
    table's expected trajectory on the f32 one)."""
    x = jnp.asarray([[0.111, -0.037, 0.5, 0.93]], jnp.float32)
    base = jnp.asarray(quant.round_key(0, 0, 0))
    acc = np.zeros_like(np.asarray(x))
    draws = 400
    for i in range(draws):
        q, s = quant.int8_stochastic(x, jax.random.fold_in(base, i))
        acc += np.asarray(quant.int8_decode(q, s))
    scale = float(np.abs(np.asarray(x)).max() / 127.0)
    # mean error shrinks like scale/sqrt(12*draws); 4 sigma of slack
    assert np.abs(acc / draws - np.asarray(x)).max() < 4 * scale / np.sqrt(
        12 * draws) + 1e-7


def test_bf16_stochastic_preserves_representable_values():
    """Values already exact in bf16 (low 16 bits zero) round to themselves
    under every key — no spurious carry."""
    x = jnp.asarray([0.5, -1.25, 3.0, 0.0, -0.09375], jnp.float32)
    for i in range(8):
        k = jax.random.fold_in(jnp.asarray(quant.round_key(1, 2, 3)), i)
        np.testing.assert_array_equal(
            np.asarray(quant.bf16_stochastic(x, k), np.float32),
            np.asarray(x))


def test_bf16_stochastic_unbiased_over_keyed_draws():
    x = jnp.asarray([[0.1001, -2.347, 7.77e-3]], jnp.float32)
    base = jnp.asarray(quant.round_key(7, 0, 0))
    acc = np.zeros((1, 3))
    draws = 400
    for i in range(draws):
        acc += np.asarray(
            quant.bf16_stochastic(x, jax.random.fold_in(base, i)),
            np.float32)
    ulp = np.abs(np.asarray(x)) * 2.0 ** -8    # bf16 ulp near x
    assert np.all(np.abs(acc / draws - np.asarray(x))
                  < 4 * ulp / np.sqrt(12 * draws) + 1e-9)


def test_round_key_is_counter_pure():
    """Same counters → same key; any counter change → different key (the
    §9 replay property the chaos digests rely on)."""
    k = quant.round_key(3, 1, 41)
    np.testing.assert_array_equal(k, quant.round_key(3, 1, 41))
    for other in [(4, 1, 41), (3, 2, 41), (3, 1, 42)]:
        assert not np.array_equal(k, quant.round_key(*other))


# ---------------------------------------------------------------------------
# TableSpec surface
# ---------------------------------------------------------------------------

def test_tablespec_parse_full_grammar():
    spec = tables_mod.parse("hot=bf16:frac=0.1,cold=int8,shards=4,"
                            "exchange=dense,master=1")
    assert spec == TableSpec(hot_dtype="bfloat16", cold_dtype="int8",
                             hot_frac=0.1, vocab_shard=True,
                             exchange="dense", master_copy=True, shards=4)
    # aliases + defaults
    spec = tables_mod.parse("hot=f32,cold=i8,shards=2")
    assert spec.hot_dtype == "float32" and spec.cold_dtype == "int8"
    assert spec.vocab_shard and spec.exchange == "exact"
    assert not tables_mod.parse("").is_mixed


@pytest.mark.parametrize("bad, match", [
    ("hot=int8", "hot-table"),                  # int8 needs cold scales
    ("hot=fp8", "hot-table"),
    ("cold=int4,shards=2", "cold-table"),
    ("cold=int8", "vocab_shard"),               # cold quant needs sharding?
    ("exchange=sloppy", "exchange"),
    ("frobnicate=1", "unknown"),
    ("hot=bf16:width=2", "sub-option"),
    ("justaword", "key=value"),
])
def test_tablespec_parse_rejects(bad, match):
    if bad == "cold=int8":
        # `cold=` implies vocab sharding in the grammar; the validation
        # error only fires when the spec is constructed directly
        with pytest.raises(ValueError, match=match):
            TableSpec(cold_dtype="int8")
    else:
        with pytest.raises(ValueError, match=match):
            tables_mod.parse(bad)


def test_tablespec_extra_roundtrip():
    spec = TableSpec(hot_dtype="bfloat16", cold_dtype="int8", hot_frac=0.2,
                     vocab_shard=True, exchange="dense", master_copy=True)
    assert TableSpec.from_extra(spec.to_extra()) == spec
    assert TableSpec.from_extra({}) == TableSpec()   # legacy checkpoints


def test_tablespec_derived_views():
    mixed = TableSpec(hot_dtype="bfloat16", cold_dtype="int8",
                      vocab_shard=True)
    assert mixed.is_mixed and mixed.needs_scales
    assert mixed.dtypes == ("bfloat16", "int8")
    f32 = TableSpec(vocab_shard=True)
    assert not f32.is_mixed and not f32.needs_scales
    assert f32.dtypes == ("float32",)


# ---------------------------------------------------------------------------
# Registry capability gating
# ---------------------------------------------------------------------------

def test_registry_rejects_unsupported_dtype_with_guidance():
    with pytest.raises(ValueError) as ei:
        resolve("pallas", vocab_shard=True, dtypes=("float32", "int8"))
    msg = str(ei.value)
    assert "int8" in msg and "master" in msg   # names the escape hatch
    assert "jnp" in msg                        # ...and a capable backend


def test_registry_resolves_capable_backend_for_int8():
    be = resolve("jnp", vocab_shard=True, dtypes=("float32", "int8"))
    assert "int8" in be.supports_dtypes
    # master_copy drops the dtype requirement entirely (f32 kernels run)
    assert resolve("pallas_interpret", vocab_shard=True, dtypes=()).name \
        == "pallas_interpret"


# ---------------------------------------------------------------------------
# ops.step + deprecated shims
# ---------------------------------------------------------------------------

def _toy_step(rng, vocab=50, d=8):
    cfg = smoke(dim=d, sentences_per_batch=4, max_sentence_len=12)
    from tests.conftest import make_distinct_negs
    tokens = rng.integers(0, vocab, size=(4, 12)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, vocab, cfg.negatives)
    lengths = np.full((4,), 12, np.int32)
    step = StepInputs(jnp.asarray(tokens), jnp.asarray(negs),
                      jnp.asarray(lengths), jnp.float32(0.025))
    w_in = jnp.asarray(rng.normal(size=(vocab, d)) * 0.1, jnp.float32)
    w_out = jnp.asarray(rng.normal(size=(vocab, d)) * 0.1, jnp.float32)
    return cfg, step, w_in, w_out


def test_sgns_update_shim_warns_and_matches_step(rng):
    cfg, step, w_in, w_out = _toy_step(rng)
    # the jitted step donates the table buffers: give each call its own copy
    out = ops.step(Tables(w_in=jnp.array(w_in), w_out=jnp.array(w_out)),
                   step, cfg, backend="jnp")
    with pytest.warns(DeprecationWarning, match="ops.step"):
        wi, wo = ops.sgns_update(jnp.array(w_in), jnp.array(w_out), step,
                                 cfg, backend="jnp")
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(out.w_in))
    np.testing.assert_array_equal(np.asarray(wo), np.asarray(out.w_out))


def test_vocab_sharded_update_shim_warns(rng):
    from repro.distributed.vocab_placement import VocabPlacement
    from repro.kernels.ops import static_for
    cfg, _, _, _ = _toy_step(rng)
    pl = VocabPlacement(vocab_size=50, hot=10, n_shards=1)
    with pytest.warns(DeprecationWarning, match="ops.step"):
        run = ops.vocab_sharded_update("jnp", static_for(cfg, 1), pl)
    assert callable(run)


def test_step_mixed_requires_round_key(rng):
    cfg, step, w_in, w_out = _toy_step(rng)
    t = Tables(w_in=quant.bf16_nearest(w_in), w_out=quant.bf16_nearest(w_out),
               spec=TableSpec(hot_dtype="bfloat16"))
    with pytest.raises(ValueError, match="round_key"):
        ops.step(t, step, cfg, backend="jnp")


def test_step_bf16_replicated_tracks_f32(rng):
    """One bf16 replicated step stays within bf16 rounding of the f32
    step (decode → identical f32 math → stochastic store)."""
    cfg, step, w_in, w_out = _toy_step(rng)
    t = Tables(w_in=quant.bf16_nearest(w_in), w_out=quant.bf16_nearest(w_out),
               spec=TableSpec(hot_dtype="bfloat16"))   # before donation
    ref = ops.step(Tables(w_in=w_in, w_out=w_out), step, cfg, backend="jnp")
    key = jnp.asarray(quant.round_key(0, 0, 0))
    out = ops.step(t, dataclasses.replace(step, round_key=key), cfg,
                   backend="jnp")
    assert out.w_in.dtype == jnp.bfloat16
    a = np.asarray(out.w_in, np.float32)
    b = np.asarray(ref.w_in)
    assert np.abs(a - b).max() < np.abs(b).max() * 2.0 ** -7  # ~2 bf16 ulps


# ---------------------------------------------------------------------------
# Training sessions: dtype plumbing + determinism
# ---------------------------------------------------------------------------

def _corpus():
    return synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                    n_sentences=200, mean_len=12, seed=0)


def _session(tables, corpus, vocab=None, **kw):
    from repro.core.trainer import TrainSession
    cfg = smoke(dim=16, sentences_per_batch=64, tables=tables)
    pipe = BatchingPipeline(corpus, cfg, vocab=vocab)
    return TrainSession(pipe, cfg, backend="jnp", **kw), pipe


def test_mixed_session_state_dtypes():
    s, _ = _session("hot=bf16:frac=0.25,cold=int8,shards=1", _corpus())
    s.train(max_batches=2)
    st = s.state
    assert st.w_in.dtype == jnp.bfloat16 and st.w_out.dtype == jnp.bfloat16
    assert st.cold_in.dtype == jnp.int8 and st.cold_out.dtype == jnp.int8
    assert st.scale_in.dtype == jnp.float32
    assert st.scale_in.shape == (s.placement.cold_pad,)
    assert s.embeddings().dtype == np.float32     # decoded view


def test_mixed_training_bit_deterministic_across_reruns():
    """Two identical mixed runs produce bit-identical quantized tables —
    the keyed stochastic rounding is replay-stable."""
    runs = []
    for _ in range(2):
        s, _ = _session("hot=bf16:frac=0.25,cold=int8,shards=1", _corpus())
        s.train(max_batches=3)
        runs.append(s.state)
    for leaf in ("w_in", "w_out", "cold_in", "cold_out", "scale_in",
                 "scale_out"):
        np.testing.assert_array_equal(
            np.asarray(getattr(runs[0], leaf)),
            np.asarray(getattr(runs[1], leaf)), err_msg=leaf)


def test_mixed_training_deterministic_across_prefetch_workers():
    """The §11 determinism smoke the CI job mirrors: async prefetch must
    not move the keyed rounding draws (keys are counter-derived, not
    order-derived)."""
    from repro.core.trainer import TrainSession
    from repro.data.prefetch import make_pipeline
    corpus = _corpus()
    states = []
    for workers in (0, 2):
        cfg = smoke(dim=16, sentences_per_batch=64,
                    tables="hot=bf16:frac=0.25,cold=int8,shards=1",
                    prefetch_workers=workers)
        s = TrainSession(make_pipeline(corpus, cfg), cfg, backend="jnp")
        s.train(max_batches=3)
        states.append(s.state)
    for leaf in ("w_in", "cold_in", "scale_in", "cold_out"):
        np.testing.assert_array_equal(
            np.asarray(getattr(states[0], leaf)),
            np.asarray(getattr(states[1], leaf)), err_msg=leaf)


def test_master_copy_fallback_trains_and_quantizes():
    s, _ = _session("hot=bf16:frac=0.25,cold=int8,shards=1,master=1",
                    _corpus())
    s.train(max_batches=2)
    assert s.state.cold_in.dtype == jnp.int8    # storage stays quantized
    assert np.isfinite(s.embeddings()).all()


# ---------------------------------------------------------------------------
# f32 ↔ mixed checkpoint restores (1 shard in-process; 2/4 in subprocess)
# ---------------------------------------------------------------------------

MIXED = "hot=bf16:frac=0.25,cold=int8,shards={n}"
F32 = "hot=f32,cold=f32,shards={n}"


def test_checkpoint_mixed_roundtrip_same_format(tmp_path):
    corpus = _corpus()
    d = str(tmp_path / "ckpt")
    s1, pipe = _session(MIXED.format(n=1), corpus, ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    s2, _ = _session(MIXED.format(n=1), corpus, vocab=pipe.vocab, ckpt_dir=d)
    assert s2.resumed_step == 2
    for leaf in ("w_in", "cold_in", "scale_in", "cold_out", "scale_out"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s1.state, leaf)),
            np.asarray(getattr(s2.state, leaf)), err_msg=leaf)
    s2.train(max_batches=1)
    assert s2.state.batches_seen == 3


def test_checkpoint_mixed_restores_into_f32_session(tmp_path):
    """mixed → f32: dequantization is exact, so the restored f32 session
    reproduces the mixed session's decoded embeddings bit-for-bit."""
    corpus = _corpus()
    d = str(tmp_path / "ckpt")
    s1, pipe = _session(MIXED.format(n=1), corpus, ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    s2, _ = _session(F32.format(n=1), corpus, vocab=pipe.vocab, ckpt_dir=d)
    assert s2.resumed_step == 2
    assert s2.state.cold_in.dtype == jnp.float32
    np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())


def test_checkpoint_f32_restores_into_mixed_session(tmp_path):
    """f32 → mixed: nearest-rounding encode, so the restored tables land
    within the per-row quantization bound of the f32 checkpoint."""
    corpus = _corpus()
    d = str(tmp_path / "ckpt")
    s1, pipe = _session(F32.format(n=1), corpus, ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    s2, _ = _session(MIXED.format(n=1), corpus, vocab=pipe.vocab, ckpt_dir=d)
    assert s2.resumed_step == 2
    assert s2.state.cold_in.dtype == jnp.int8
    a, b = s1.embeddings(), s2.embeddings()
    amax = np.abs(a).max()
    assert np.abs(a - b).max() <= amax / 254 + amax * 2.0 ** -9 + 1e-7
    s2.train(max_batches=1)   # and keeps training in mixed precision
    assert s2.state.batches_seen == 3


@pytest.mark.parametrize("n_shards", [2, 4])
def test_checkpoint_f32_mixed_cross_restore_sharded(subproc, n_shards):
    """Both restore directions on a real N-shard mesh: mixed → f32 exact,
    f32 → mixed within the nearest-encode bound."""
    r = subproc("""
        import numpy as np, jax, tempfile
        N = %d
        assert jax.device_count() == N
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import TrainSession
        from repro.launch.mesh import make_host_mesh

        corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                          n_sentences=200, mean_len=12,
                                          seed=0)
        mixed = "hot=bf16:frac=0.25,cold=int8,shards=%%d" %% N
        f32 = "hot=f32,cold=f32,shards=%%d" %% N

        def session(tables, vocab=None, **kw):
            cfg = smoke(dim=16, sentences_per_batch=64, tables=tables)
            pipe = BatchingPipeline(corpus, cfg, vocab=vocab)
            return TrainSession(pipe, cfg, backend="jnp",
                                mesh=make_host_mesh(model=1), **kw), pipe

        # mixed -> f32 (exact: decode is a multiply)
        d1 = tempfile.mkdtemp()
        s1, pipe = session(mixed, ckpt_dir=d1, ckpt_every=2)
        s1.train(max_batches=2)
        assert str(s1.state.cold_in.dtype) == "int8"
        s2, _ = session(f32, vocab=pipe.vocab, ckpt_dir=d1)
        assert s2.resumed_step == 2
        np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())

        # f32 -> mixed (nearest encode: bounded)
        d2 = tempfile.mkdtemp()
        s3, _ = session(f32, vocab=pipe.vocab, ckpt_dir=d2, ckpt_every=2)
        s3.train(max_batches=2)
        s4, _ = session(mixed, vocab=pipe.vocab, ckpt_dir=d2)
        assert s4.resumed_step == 2
        assert str(s4.state.cold_in.dtype) == "int8"
        a, b = s3.embeddings(), s4.embeddings()
        amax = np.abs(a).max()
        assert np.abs(a - b).max() <= amax / 254 + amax * 2.0 ** -9 + 1e-7
        s4.train(max_batches=1)
        assert s4.state.batches_seen == 3
        print("OK")
    """ % n_shards, n_devices=n_shards)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_serve_loads_quantized_checkpoint(tmp_path):
    """serve/index reads storage dtypes from the manifest: an int8 split
    checkpoint stages with the same normalized rows as the trainer's
    decoded view, including a shard-count change (re-stripe in storage
    precision, scales riding along)."""
    from repro.serve.index import EmbeddingIndex
    corpus = _corpus()
    d = str(tmp_path / "ckpt")
    s1, _ = _session(MIXED.format(n=1), corpus, ckpt_dir=d, ckpt_every=2)
    s1.train(max_batches=2)
    idx = EmbeddingIndex.load(d)
    assert idx.vocab_size == s1.placement.vocab_size
    hot, cold, _ = s1.embeddings_sharded()
    want = np.array(hot, np.float32)
    want /= np.maximum(np.linalg.norm(want, axis=-1, keepdims=True), 1e-12)
    np.testing.assert_allclose(np.asarray(idx.hot), want, rtol=1e-6,
                               atol=1e-7)
