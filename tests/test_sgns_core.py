"""Properties of the canonical SGNS window math (core/sgns.py)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.sgns import pair_delta, stable_sigmoid, window_delta


@given(st.floats(-50, 50))
@settings(max_examples=50, deadline=None)
def test_stable_sigmoid_matches_jax(x):
    a = float(stable_sigmoid(jnp.float32(x)))
    b = float(jax.nn.sigmoid(jnp.float32(x)))
    assert abs(a - b) < 1e-6
    assert 0.0 <= a <= 1.0


@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_window_delta_equals_pair_sum(k, n_out, seed):
    """The shared-negative window GEMM == the sum of independent pairings
    computed from pre-update values — the commutativity FULL-W2V §3.1
    exploits."""
    rng = np.random.default_rng(seed)
    d = 16
    ctx = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    out = jnp.asarray(rng.normal(size=(n_out, d)), jnp.float32)
    mask = jnp.asarray(rng.random(k) < 0.8)
    lr = jnp.float32(0.1)

    d_ctx, d_out = window_delta(ctx, out, mask, lr)

    exp_ctx = np.zeros((k, d), np.float32)
    exp_out = np.zeros((n_out, d), np.float32)
    for i in range(k):
        if not bool(mask[i]):
            continue
        for j in range(n_out):
            label = jnp.float32(1.0 if j == 0 else 0.0)
            di, do = pair_delta(ctx[i], out[j], label, lr)
            exp_ctx[i] += np.asarray(di)
            exp_out[j] += np.asarray(do)
    np.testing.assert_allclose(np.asarray(d_ctx), exp_ctx, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d_out), exp_out, atol=1e-5)


def test_window_delta_masked_rows_are_zero():
    rng = np.random.default_rng(1)
    ctx = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    out = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    mask = jnp.array([True, False, True, False])
    d_ctx, _ = window_delta(ctx, out, mask, jnp.float32(0.5))
    assert float(jnp.abs(d_ctx[1]).max()) == 0.0
    assert float(jnp.abs(d_ctx[3]).max()) == 0.0
    assert float(jnp.abs(d_ctx[0]).max()) > 0.0


def test_gradient_direction_positive_pair():
    """A positive pair must move the context vector toward the target."""
    ctx = jnp.ones((1, 8), jnp.float32) * 0.1
    out = jnp.ones((1, 8), jnp.float32) * 0.1
    d_ctx, d_out = window_delta(ctx, out, jnp.array([True]), jnp.float32(1.0))
    # label 1, sigmoid(0.08) ≈ 0.52 -> g > 0 -> delta along out
    assert float(d_ctx[0, 0]) > 0
    assert float(d_out[0, 0]) > 0
