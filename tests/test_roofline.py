"""Roofline extraction: collective parser + term arithmetic + analytic
memory model sanity."""
import numpy as np

from repro.configs.base import SHAPES, get_arch
from repro.launch.costmodel import memory_bytes
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    collective_bytes,
)

HLO = """
ENTRY %main {
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[256]{0} all-reduce(%y), replica_groups=[2,8]<=[16], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%a, %b)
}
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    # all-gather: 16*1024*2 bytes * (4-1)/4
    assert abs(out["all-gather"] - 16 * 1024 * 2 * 0.75) < 1
    # all-reduce: 256*4 * 2*(8-1)/8   (iota groups [2,8] -> size 8)
    assert abs(out["all-reduce"] - 256 * 4 * 2 * 7 / 8) < 1
    # reduce-scatter: result 64*4 * (2-1)
    assert abs(out["reduce-scatter"] - 64 * 4) < 1
    assert abs(out["collective-permute"] - 32 * 32 * 2) < 1
    assert "dot" not in out
    assert out["total"] > 0


def test_terms_and_bottleneck():
    t = RooflineTerms(flops=PEAK_FLOPS, bytes_accessed=HBM_BW / 2,
                      coll_bytes=ICI_BW / 4, coll_breakdown={},
                      model_flops=PEAK_FLOPS / 2)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 0.5) < 1e-9
    assert abs(t.t_collective - 0.25) < 1e-9
    assert t.bottleneck == "compute"
    assert abs(t.roofline_frac - 0.5) < 1e-9
    assert abs(t.useful_flops_frac - 0.5) < 1e-9


def test_memory_model_orderings():
    """Decode is cache-dominated; train params cost more than serve."""
    cfg = get_arch("qwen3-8b")
    train = memory_bytes(cfg, SHAPES["train_4k"])
    dec = memory_bytes(cfg, SHAPES["decode_32k"])
    assert train["total"] > 0 and dec["total"] > 0
    assert dec["cache"] > 0 and train["cache"] == 0
    # decode for a 32k cache at batch 128 is dominated by cache reads
    assert dec["cache"] > dec["layers"]
    # train moves far more layer-activation bytes than decode
    assert train["layers"] > 100 * dec["layers"]


def test_memory_model_moe_vs_dense():
    """MoE traffic reflects activated capacity, not total experts."""
    arctic = get_arch("arctic-480b")
    t = memory_bytes(arctic, SHAPES["train_4k"])
    # per-device param+opt traffic of 480B params over 256 devices
    assert t["params_opt"] > 1e9
    assert np.isfinite(t["total"])
