"""Multi-device integration tests (subprocess: jax locks device count at
init). Small meshes of fake host devices exercise the same pjit/shard_map
paths as the production mesh."""
import json

import pytest


def test_w2v_hogwild_data_parallel(subproc):
    """W2V trainer with sentences sharded over a 4-way data axis + model
    averaging matches single-device quality."""
    r = subproc("""
        import numpy as np, jax
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import W2VTrainer
        from repro.core.quality import evaluate
        from repro.launch.mesh import make_host_mesh

        # Hogwild model averaging dilutes per-replica updates ~1/n_dev per
        # sync, so convergence needs more epochs than single-device
        cfg = smoke(epochs=10, dim=32, sentences_per_batch=64)
        corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                          n_sentences=400, mean_len=12, seed=0)
        pipe = BatchingPipeline(corpus, cfg)
        mesh = make_host_mesh(model=1)
        tr = W2VTrainer(pipe, cfg, backend="jnp", mesh=mesh)
        tr.train()
        inv = np.zeros(pipe.vocab.size, dtype=int)
        for w, i in pipe.vocab.ids.items():
            inv[i] = corpus.clusters[w]
        # averaging divides the effective LR by n_dev, so absolute cosine
        # separation stays small at equal epochs; the scale-invariant
        # metrics (ranking + neighbour purity) show the structure is learned
        m = evaluate(tr.embeddings(), inv, seed=0)
        assert m["spearman"] > 0.3, m
        assert m["nn_purity"] > 0.6, m
        assert m["separation"] > 0.01, m
        print("OK", m["separation"])
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_w2v_mesh_tiled_parity(subproc):
    """Mesh × window-tiling composition (engine API): a sharded tiled step
    at T>1 must equal the average of per-shard single-device tiled updates
    (that IS the Hogwild semantics), and a T=1-plan batch under the mesh
    must stay bit-identical to the sequential mesh path."""
    r = subproc("""
        import numpy as np, jax, jax.numpy as jnp
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline, Batch, plan_tiles
        from repro.core.trainer import TrainSession, init_state
        from repro.kernels import ops
        from repro.kernels.registry import StepInputs
        from repro.launch.mesh import make_host_mesh

        cfg = smoke(tile_windows=4, dim=128, sentences_per_batch=64)
        corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                          n_sentences=200, mean_len=10,
                                          seed=0)
        pipe = BatchingPipeline(corpus, cfg)
        mesh = make_host_mesh(model=1)
        sess = TrainSession(pipe, cfg, backend="jnp", mesh=mesh)
        batch = next(pipe.batches(pad_len=cfg.resolved_pad_len))
        lr = sess.current_lr()

        # --- T>1: sharded step == mean of per-shard single-device tiled ---
        sess.train_batch(batch)
        sharded_in = np.asarray(sess.state.w_in)
        sharded_out = np.asarray(sess.state.w_out)
        st = init_state(pipe.vocab.size, cfg, cfg.seed)
        S = batch.tokens.shape[0]; shard = S // 4
        p = batch.plan
        ins, outs = [], []
        for i in range(4):
            sl = slice(i * shard, (i + 1) * shard)
            step = StepInputs(
                jnp.asarray(batch.tokens[sl]), jnp.asarray(batch.negs[sl]),
                jnp.asarray(batch.lengths[sl]), jnp.float32(lr),
                jnp.asarray(p.uniq[sl]), jnp.asarray(p.scatter[sl]),
                jnp.asarray(p.ucount[sl]), jnp.asarray(p.strict[sl]))
            wi, wo = ops.sgns_update(jnp.array(st.w_in), jnp.array(st.w_out),
                                     step, cfg, backend="jnp")
            ins.append(np.asarray(wi)); outs.append(np.asarray(wo))
        np.testing.assert_allclose(sharded_in, np.mean(ins, axis=0),
                                   atol=1e-6, rtol=1e-5)
        np.testing.assert_allclose(sharded_out, np.mean(outs, axis=0),
                                   atol=1e-6, rtol=1e-5)

        # --- T=1 plan under the mesh == sequential mesh path, bit-exact ---
        seq_pipe = BatchingPipeline(corpus, smoke(dim=128,
                                                  sentences_per_batch=64),
                                    vocab=pipe.vocab)
        sb = next(seq_pipe.batches(pad_len=cfg.resolved_pad_len))
        plan1 = plan_tiles(sb.tokens, sb.negs, sb.lengths, 1)
        tiled_b = Batch(tokens=sb.tokens, negs=sb.negs, lengths=sb.lengths,
                        n_words=sb.n_words, plan=plan1)
        s_seq = TrainSession(seq_pipe, sess.cfg, backend="jnp", mesh=mesh)
        s_til = TrainSession(seq_pipe, sess.cfg, backend="jnp", mesh=mesh)
        s_seq.train_batch(sb)
        s_til.train_batch(tiled_b)
        assert (np.asarray(s_seq.state.w_in)
                == np.asarray(s_til.state.w_in)).all()
        assert (np.asarray(s_seq.state.w_out)
                == np.asarray(s_til.state.w_out)).all()
        print("OK parity")
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK parity" in r.stdout


def test_w2v_mesh_tiled_training_quality(subproc):
    """W2VTrainer(mesh=..., cfg.tile_windows>1) trains successfully: the
    combination the old trainer refused with NotImplementedError. Quality
    thresholds match the sequential Hogwild test."""
    r = subproc("""
        import numpy as np, jax
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import W2VTrainer
        from repro.core.quality import evaluate
        from repro.launch.mesh import make_host_mesh

        cfg = smoke(epochs=10, dim=32, sentences_per_batch=64,
                    tile_windows=4)
        corpus = synthetic_cluster_corpus(n_clusters=6, words_per_cluster=12,
                                          n_sentences=400, mean_len=12,
                                          seed=0)
        pipe = BatchingPipeline(corpus, cfg)
        mesh = make_host_mesh(model=1)
        tr = W2VTrainer(pipe, cfg, backend="jnp", mesh=mesh)
        assert tr.backend == "jnp_tiled"
        tr.train()
        inv = np.zeros(pipe.vocab.size, dtype=int)
        for w, i in pipe.vocab.ids.items():
            inv[i] = corpus.clusters[w]
        m = evaluate(tr.embeddings(), inv, seed=0)
        assert m["spearman"] > 0.3, m
        assert m["nn_purity"] > 0.6, m
        assert m["separation"] > 0.01, m
        print("OK", m["separation"])
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_w2v_vocab_shard_mesh_parity(subproc):
    """Vocab-sharded training on a 4-way data mesh (hot head replicated,
    cold tail striped over shards, per-step distinct-row exchange) matches
    the replicated Hogwild path: hot rows bit-identically, cold rows within
    the DESIGN.md §8 float tolerance — for both the sequential and the
    window-tiled kernel families. Also checks the per-device cold shard is
    really ~cold/N rows."""
    r = subproc("""
        import numpy as np, jax
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import TrainSession
        from repro.launch.mesh import make_host_mesh

        corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                          n_sentences=400, mean_len=12,
                                          seed=0)
        mesh = make_host_mesh(model=1)
        for tw in (1, 4):
            cfg = smoke(dim=32, sentences_per_batch=64, tile_windows=tw)
            cfg_vs = smoke(dim=32, sentences_per_batch=64, tile_windows=tw,
                           vocab_shard=True, hot_vocab_frac=0.25)
            pipe = BatchingPipeline(corpus, cfg)
            pipe_vs = BatchingPipeline(corpus, cfg_vs, vocab=pipe.vocab)
            a = TrainSession(pipe, cfg, backend="jnp", mesh=mesh)
            b = TrainSession(pipe_vs, cfg_vs, backend="jnp", mesh=mesh)
            a.train(max_batches=4)
            b.train(max_batches=4)
            pl = b.placement
            assert pl.n_shards == 4
            assert pl.cold_per_shard == -(-pl.cold // 4)
            ea, eb = a.embeddings(), b.embeddings()
            assert (ea[:pl.hot] == eb[:pl.hot]).all(), "hot head diverged"
            np.testing.assert_allclose(ea[pl.hot:], eb[pl.hot:],
                                       atol=1e-6, rtol=1e-5)
            print(f"OK T={tw} hot={pl.hot} cold/dev={pl.cold_per_shard}",
                  float(np.abs(ea[pl.hot:] - eb[pl.hot:]).max()))
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK T=1" in r.stdout and "OK T=4" in r.stdout


def test_w2v_vocab_shard_exchange_flavors_agree(subproc):
    """Request-exact bucketed all_to_all vs the dense all_gather +
    psum_scatter exchange on a 4-way mesh: same training, different
    collective schedule — hot head bit-identical, cold tail within the §8
    float tolerance (summation order differs across schedules)."""
    r = subproc("""
        import numpy as np, jax
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import TrainSession
        from repro.launch.mesh import make_host_mesh

        corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                          n_sentences=400, mean_len=12,
                                          seed=0)
        mesh = make_host_mesh(model=1)
        cfg_vs = smoke(dim=32, sentences_per_batch=64, vocab_shard=True,
                       hot_vocab_frac=0.25)
        pipe = BatchingPipeline(corpus, cfg_vs)
        runs = {}
        for flavor in ("dense", "exact"):
            s = TrainSession(BatchingPipeline(corpus, cfg_vs,
                                              vocab=pipe.vocab),
                             cfg_vs, backend="jnp", mesh=mesh,
                             exchange=flavor)
            s.train(max_batches=4)
            runs[flavor] = (s.embeddings(), s.placement)
        (ea, pl), (eb, _) = runs["dense"], runs["exact"]
        assert pl.n_shards == 4
        assert (ea[:pl.hot] == eb[:pl.hot]).all(), "hot head diverged"
        np.testing.assert_allclose(ea[pl.hot:], eb[pl.hot:],
                                   atol=1e-6, rtol=1e-5)
        print("OK flavors", float(np.abs(ea[pl.hot:] - eb[pl.hot:]).max()))
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK flavors" in r.stdout


def test_w2v_vocab_shard_fused_gather_mesh(subproc):
    """The fused-gather tiled backend (split-table DMA stream) trains on a
    real 2-shard mesh under both exchange flavors and agrees with itself:
    hot bitwise, cold within tolerance. Interpret mode, so sizes are kept
    tiny."""
    r = subproc("""
        import numpy as np, jax
        assert jax.device_count() == 2
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import TrainSession
        from repro.kernels import registry
        from repro.launch.mesh import make_host_mesh

        assert registry.get("pallas_tiled_interpret").supports_fused_gather
        corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=16,
                                          n_sentences=40, mean_len=10,
                                          seed=0)
        mesh = make_host_mesh(model=1)
        cfg_vs = smoke(dim=128, sentences_per_batch=4, max_sentence_len=16,
                       tile_windows=4, vocab_shard=True, hot_vocab_frac=0.25)
        pipe = BatchingPipeline(corpus, cfg_vs)
        runs = {}
        for flavor in ("dense", "exact"):
            s = TrainSession(BatchingPipeline(corpus, cfg_vs,
                                              vocab=pipe.vocab),
                             cfg_vs, backend="pallas_tiled_interpret",
                             mesh=mesh, exchange=flavor)
            s.train(max_batches=1)
            runs[flavor] = (s.embeddings(), s.placement)
        (ea, pl), (eb, _) = runs["dense"], runs["exact"]
        assert pl.n_shards == 2
        assert (ea[:pl.hot] == eb[:pl.hot]).all(), "hot head diverged"
        np.testing.assert_allclose(ea[pl.hot:], eb[pl.hot:],
                                   atol=1e-6, rtol=1e-5)
        print("OK fused mesh", pl.hot)
    """, n_devices=2, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK fused mesh" in r.stdout


def test_w2v_vocab_shard_mesh_checkpoint_to_replicated(subproc):
    """A split-table checkpoint written on a 4-shard mesh restores into a
    single-device replicated session with identical embeddings."""
    r = subproc("""
        import numpy as np, jax, tempfile
        assert jax.device_count() == 4
        from repro.configs.w2v import smoke
        from repro.data.corpus import synthetic_cluster_corpus
        from repro.data.batching import BatchingPipeline
        from repro.core.trainer import TrainSession
        from repro.launch.mesh import make_host_mesh

        corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                          n_sentences=400, mean_len=12,
                                          seed=0)
        cfg_vs = smoke(dim=32, sentences_per_batch=64, vocab_shard=True,
                       hot_vocab_frac=0.25, epochs=2)
        d = tempfile.mkdtemp()
        pipe = BatchingPipeline(corpus, cfg_vs)
        s1 = TrainSession(pipe, cfg_vs, backend="jnp",
                          mesh=make_host_mesh(model=1), ckpt_dir=d,
                          ckpt_every=2)
        s1.train(max_batches=2)
        cfg = smoke(dim=32, sentences_per_batch=64, epochs=2)
        s2 = TrainSession(BatchingPipeline(corpus, cfg, vocab=pipe.vocab),
                          cfg, backend="jnp", ckpt_dir=d)
        assert s2.resumed_step == 2 and s2.placement is None
        np.testing.assert_array_equal(s1.embeddings(), s2.embeddings())

        # regression: restore the 4-shard checkpoint into a 2-shard
        # session whose split shapes COINCIDE (V=128, hot=32, cold=96:
        # cold_pad 96 for both) but whose stripe layouts differ — the
        # restore must re-split through the placements, not copy raw
        s3 = TrainSession(BatchingPipeline(corpus, cfg_vs,
                                           vocab=pipe.vocab),
                          cfg_vs, backend="jnp",
                          mesh=make_host_mesh(model=2), ckpt_dir=d)
        assert s3.placement.n_shards == 2
        assert (s3.placement.cold_pad == s1.placement.cold_pad
                and s3.placement.hot == s1.placement.hot)
        np.testing.assert_array_equal(s1.embeddings(), s3.embeddings())
        print("OK ckpt")
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK ckpt" in r.stdout


def test_small_mesh_dryrun_train_and_serve(subproc):
    """build_cell lowers + compiles on an 8-device (2,2,2) pod mesh for a
    reduced arch — the same code path as the 512-device production run."""
    r = subproc("""
        import os
        import jax, dataclasses
        assert jax.device_count() == 8
        import jax.numpy as jnp
        from repro.configs import get_smoke, SHAPES
        from repro.configs.base import InputShape
        from repro.launch.steps import build_cell
        from repro.launch.roofline import analyze

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = dataclasses.replace(get_smoke("qwen3-8b"), n_heads=4,
                                  n_kv_heads=2)
        SHAPES["tiny_train"] = InputShape("tiny_train", 64, 8, "train")
        SHAPES["tiny_decode"] = InputShape("tiny_decode", 64, 8, "decode")
        for shape in ["tiny_train", "tiny_decode"]:
            jit, args, rules = build_cell(cfg, shape, mesh,
                                          param_dtype=jnp.float32)
            compiled = jit.lower(*args).compile()
            t = analyze(compiled)
            assert t.flops > 0
            print(shape, "ok", t.bottleneck)
    """, n_devices=8)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "tiny_train ok" in r.stdout and "tiny_decode ok" in r.stdout


def test_train_step_executes_on_mesh(subproc):
    """A real (non-abstract) sharded train step runs and the loss is
    finite on a 4-device mesh."""
    r = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.distributed.sharding import Rules, param_shardings
        from repro.launch.steps import make_train_step, batch_shardings
        from repro.models import lm
        from repro.train.optim import AdamWConfig, adamw_init

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_smoke("starcoder2-3b")
        rules = Rules(mesh)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, rules))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=5)),
                       donate_argnums=(0, 1))
        rng = np.random.default_rng(0)
        from repro.distributed.sharding import axis_rules
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                       jnp.int32)}
        with axis_rules(mesh):
            for _ in range(3):
                params, opt, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_checkpoint_reshard_across_meshes(subproc):
    """Save sharded on a (4,)-data mesh, restore onto a (2,2) mesh —
    the elastic-restart path."""
    r = subproc("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        d = tempfile.mkdtemp()
        mesh1 = jax.make_mesh((4,), ("data",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        x = jax.device_put(x, NamedSharding(mesh1, P("data", None)))
        ckpt.save(d, 3, {"x": x})

        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        target = NamedSharding(mesh2, P("data", "model"))
        out, _ = ckpt.restore(d, {"x": jax.ShapeDtypeStruct((8, 8),
                                                            jnp.float32)},
                              shardings={"x": target})
        assert out["x"].sharding == target
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.arange(64).reshape(8, 8))
        print("OK")
    """, n_devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
