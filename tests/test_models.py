"""Per-architecture smoke tests (reduced same-family configs) + SSD/flash
correctness against naive references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import lm
from repro.models.layers import causal_attention
from repro.models.ssm import ssd_chunked

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch, rng):
    """One forward + one grad step on CPU: shapes + finiteness."""
    cfg = get_smoke(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    pref = None
    if cfg.prefix_len:
        pref = jnp.asarray(rng.normal(size=(B, cfg.prefix_len, cfg.d_model)),
                           jnp.float32)
    logits = lm.forward(cfg, params, toks, pref)
    assert logits.shape == (B, S + cfg.prefix_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(cfg, p, toks, labels, pref))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b", "arctic-480b"])
def test_prefill_decode_matches_forward(arch, rng):
    """decode(prefill(x[:S])) logits == forward(x[:S+1]) at position S."""
    cfg = get_smoke(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    pref = None
    if cfg.prefix_len:
        pref = jnp.asarray(rng.normal(size=(B, cfg.prefix_len, cfg.d_model)),
                           jnp.float32)
    full = lm.forward(cfg, params, toks, pref)
    _, cache, clen = lm.prefill(cfg, params, toks[:, :S], pref,
                                cache_dtype=jnp.float32)

    def pad_kv(c):
        out = []
        for blk in c:
            nb = {}
            for k, v in blk.items():
                nb[k] = jnp.pad(v, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]) \
                    if k in ("k", "v") else v
            out.append(nb)
        return tuple(out)

    dec, _ = lm.decode_step(cfg, params, pad_kv(cache), clen,
                            toks[:, S:S + 1])
    ref = np.asarray(full[:, cfg.prefix_len + S])
    err = np.abs(ref - np.asarray(dec)).max() / (np.abs(ref).max() + 1e-9)
    assert err < 1e-4, err


def test_unrolled_matches_scanned(rng):
    """scan_layers=False (analysis path) must be numerically identical."""
    cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    a = lm.forward(cfg, params, toks)
    b = lm.forward(dataclasses.replace(cfg, scan_layers=False), params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_matches_naive(rng):
    b, s, nh, nkv, hd = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, nkv, hd)), jnp.float32)
    out = causal_attention(q, k, v, n_q_chunks=5, n_kv_chunks=3)

    # naive reference
    qg = q.reshape(b, s, nkv, nh // nkv, hd)
    logits = jnp.einsum("bqkgh,bskh->bqkgs", qg, k) / hd ** 0.5
    ii = jnp.arange(s)
    causal = ii[:, None] >= ii[None, :]                    # (q, s)
    logits = jnp.where(causal[None, :, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    ref = jnp.einsum("bqkgs,bskh->bqkgh", w, v).reshape(b, s, nh, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_ssd_chunked_matches_sequential(rng):
    """Chunked SSD dual form == token-by-token linear recurrence."""
    b, l, h, p, g, s, chunk = 1, 24, 2, 4, 1, 8, 8
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 1.0, size=(h,)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(b, l, g, s)), jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(b, l, g, s)), jnp.float32)

    y, state = ssd_chunked(x, dt, a, bmat, cmat, chunk)

    # sequential recurrence: st = st*exp(dt*a) + dt*B⊗x ; y = C·st
    st = np.zeros((b, h, p, s), np.float32)
    ys = np.zeros((b, l, h, p), np.float32)
    for t in range(l):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a))   # (b,h)
        bt = np.repeat(np.asarray(bmat[:, t]), h // g, 1)       # (b,h,s)
        ct = np.repeat(np.asarray(cmat[:, t]), h // g, 1)
        xt = np.asarray(x[:, t])                                # (b,h,p)
        st = (st * decay[:, :, None, None]
              + np.einsum("bh,bhs,bhp->bhps", np.asarray(dt[:, t]), bt, xt))
        ys[:, t] = np.einsum("bhs,bhps->bhp", ct, st)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), st, atol=1e-4, rtol=1e-3)


def test_param_count_matches_init(rng):
    """Analytic param_count (used for MODEL_FLOPS) == actual init size."""
    for arch in ["qwen3-8b", "mamba2-1.3b", "arctic-480b",
                 "jamba-1.5-large-398b"]:
        cfg = get_smoke(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.param_count(), (arch, actual, cfg.param_count())
