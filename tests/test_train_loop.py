"""LM Trainer loop: loss goes down, checkpoint/restart, failure recovery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import AdamWConfig
from repro.train.resilience import FailureInjector


def _trainer(tmp_path=None, steps=12, injector=None, **kw):
    cfg = get_smoke("starcoder2-3b")
    loop = LoopConfig(steps=steps,
                      ckpt_dir=str(tmp_path) if tmp_path else None,
                      ckpt_every=4, log_every=100)
    opt = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2)
    return Trainer(cfg, opt, loop, batch=2, seq=16,
                   failure_injector=injector, **kw)


def test_loss_decreases():
    tr = _trainer(steps=15)
    out = tr.train()
    losses = out["losses"]
    assert out["final_step"] == 15
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_checkpoint_resume(tmp_path):
    tr = _trainer(tmp_path, steps=6)
    tr.train()
    # new trainer picks up at the checkpointed step
    tr2 = _trainer(tmp_path, steps=10)
    assert tr2.start_step == 6
    out = tr2.train()
    assert out["final_step"] == 10


def test_failure_recovery_with_checkpoint(tmp_path):
    inj = FailureInjector([5, 9])
    tr = _trainer(tmp_path, steps=12, injector=inj)
    out = tr.train()
    assert out["final_step"] == 12
    assert not inj.fail_steps          # both failures consumed
    assert all(np.isfinite(l) for l in out["losses"])


def test_failure_without_checkpoint_still_completes():
    inj = FailureInjector([3])
    tr = _trainer(None, steps=6, injector=inj)
    out = tr.train()
    assert out["final_step"] == 6
