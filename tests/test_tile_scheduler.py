"""Property tests for the conflict-aware tile scheduler
(`repro.data.batching.plan_tiles`, DESIGN.md §4)."""
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.data.batching import plan_costs, plan_tiles
from tests.conftest import make_distinct_negs


def _random_batch(rng, S, L, V, N):
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    negs = make_distinct_negs(rng, tokens, V, N)
    lengths = rng.integers(0, L + 1, size=(S,)).astype(np.int32)
    return tokens, negs, lengths


@given(st.integers(1, 6), st.integers(1, 16), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_scatter_round_trips(tile, L, n_neg, seed):
    """compact → scatter == original rows, for every valid slot."""
    rng = np.random.default_rng(seed)
    V = max(n_neg + 2, int(rng.integers(n_neg + 2, 20)))
    tokens, negs, lengths = _random_batch(rng, 2, L, V, n_neg)
    plan = plan_tiles(tokens, negs, lengths, tile)
    m = n_neg + 1
    for s in range(2):
        for i in range(plan.n_tiles):
            t0 = i * tile
            for w in range(tile):
                t = t0 + w
                if t >= lengths[s]:
                    continue
                rows = [tokens[s, t]] + list(negs[s, t])
                for j, row in enumerate(rows):
                    col = plan.scatter[s, i, w * m + j]
                    assert col < plan.ucount[s, i]
                    assert plan.uniq[s, i, col] == row, (s, i, w, j)


@given(st.integers(1, 6), st.integers(1, 16), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_strict_iff_target_involved_repeat(tile, L, n_neg, seed):
    """strict is set exactly when a row repeated intra-tile involves a
    target slot (target/target or target-as-negative collision); pure
    negative/negative repeats are fused via dedup (DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    V = max(n_neg + 2, int(rng.integers(n_neg + 2, 15)))
    tokens, negs, lengths = _random_batch(rng, 2, L, V, n_neg)
    plan = plan_tiles(tokens, negs, lengths, tile)
    for s in range(2):
        for i in range(plan.n_tiles):
            rows, targets = [], []
            for w in range(tile):
                t = i * tile + w
                if t >= lengths[s]:
                    continue
                targets.append(tokens[s, t])
                rows += [tokens[s, t]] + list(negs[s, t])
            counts = {r: rows.count(r) for r in rows}
            target_hit = any(counts[t] > 1 for t in targets)
            assert bool(plan.strict[s, i]) == target_hit, (s, i, rows)
            assert plan.ucount[s, i] == len(set(rows))


def test_t1_layout_matches_sequential_kernel(rng):
    """At T=1 the compacted rows are exactly [target, neg_1..neg_N] — the
    sequential kernel's VMEM layout (prerequisite for bit-identity)."""
    V, L, N = 40, 9, 3
    tokens, negs, lengths = _random_batch(rng, 3, L, V, N)
    plan = plan_tiles(tokens, negs, lengths, 1)
    assert plan.n_tiles == L
    assert not plan.strict.any()      # distinct-negatives invariant holds
    for s in range(3):
        for t in range(lengths[s]):
            expect = [tokens[s, t]] + list(negs[s, t])
            assert list(plan.uniq[s, t, :N + 1]) == expect
            assert list(plan.scatter[s, t]) == list(range(N + 1))
            assert plan.ucount[s, t] == N + 1


def test_padding_masked(rng):
    """uniq columns past ucount and scatter slots of out-of-sentence windows
    are zeroed (the kernel masks them but never reads garbage)."""
    V, L, N, tile = 12, 10, 2, 4
    tokens, negs, lengths = _random_batch(rng, 2, L, V, N)
    lengths[:] = [3, 0]               # force partial + empty sentences
    plan = plan_tiles(tokens, negs, lengths, tile)
    m = N + 1
    for s in range(2):
        for i in range(plan.n_tiles):
            u = plan.ucount[s, i]
            assert (plan.uniq[s, i, u:] == 0).all()
            n_valid = max(0, min(tile, lengths[s] - i * tile))
            assert (plan.scatter[s, i, n_valid * m:] == 0).all()
    assert plan.ucount[1].sum() == 0


def test_tile_shared_negatives_invariants(rng):
    """`sample_batch_tiled`: per-tile sets are internally distinct, avoid
    every target of their tile, and are broadcast to all tile windows — so
    the per-window kernel invariant holds and tiles only go strict on
    target/target repeats."""
    from repro.data.negatives import NegativeSampler

    V, S, L, N, tile = 50, 3, 17, 4, 4
    sampler = NegativeSampler(np.ones(V), seed=3)
    tokens = rng.integers(0, V, size=(S, L)).astype(np.int32)
    lengths = rng.integers(1, L + 1, size=(S,)).astype(np.int32)
    negs = sampler.sample_batch_tiled(tokens, N, tile, lengths)
    assert negs.shape == (S, L, N)
    for s in range(S):
        for i in range(-(-L // tile)):
            t0 = i * tile
            wins = [t for t in range(t0, min(t0 + tile, L))]
            sets = {tuple(negs[s, t]) for t in wins}
            assert len(sets) == 1                  # shared across the tile
            ns = negs[s, t0]
            assert len(set(ns)) == N               # internally distinct
            for t in wins:
                if t < lengths[s]:
                    assert tokens[s, t] not in ns  # never a tile target
    plan = plan_tiles(tokens, negs, lengths, tile)
    costs = plan_costs(plan, lengths, N)
    assert costs["dma_per_window"] < 2 + 2 * (N + 1)   # dedup took effect


def test_tile_shared_negatives_infeasible_raises():
    """A vocab too small to supply N negatives distinct from a tile's
    targets must fail fast instead of spinning in the fallback walk."""
    import pytest

    from repro.data.negatives import NegativeSampler

    V, tile, N = 6, 4, 4
    sampler = NegativeSampler(np.ones(V), seed=0)
    tokens = np.arange(4, dtype=np.int32)[None, :]   # 4 distinct targets
    with pytest.raises(ValueError, match="cannot draw"):
        sampler.sample_batch_tiled(tokens, N, tile,
                                   np.array([4], np.int32))


def test_plan_costs_t1_equals_sequential():
    """The replayed cost model at T=1 reproduces the sequential kernel's
    per-window DMA and GEMM counts (2 ring + 2(N+1) rows, 3 GEMMs)."""
    rng = np.random.default_rng(0)
    V, L, N = 50, 16, 5
    tokens, negs, lengths = _random_batch(rng, 4, L, V, N)
    plan = plan_tiles(tokens, negs, lengths, 1)
    costs = plan_costs(plan, lengths, N)
    assert costs["windows"] == int(lengths.sum())
    assert costs["dma_per_window"] == 2 + 2 * (N + 1)
    assert costs["gemms_per_window"] == 3.0


def test_plan_costs_tiling_reduces_gemms():
    rng = np.random.default_rng(1)
    V, L, N, tile = 500, 32, 5, 8
    tokens, negs, lengths = _random_batch(rng, 4, L, V, N)
    lengths[:] = L                    # full sentences
    p1 = plan_costs(plan_tiles(tokens, negs, lengths, 1), lengths, N)
    p8 = plan_costs(plan_tiles(tokens, negs, lengths, tile), lengths, N)
    # collision-free tiles collapse 3 GEMMs/window to 3 GEMMs/tile
    assert p8["gemms_per_window"] < p1["gemms_per_window"]
    assert p8["dma_per_window"] <= p1["dma_per_window"]
