"""Optimizer, checkpointing, resilience, compression, elastic planning."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.distributed import compression as comp
from repro.distributed.elastic import degrade_sequence, plan_mesh
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.train.resilience import (
    FailureInjector,
    RetryPolicy,
    StragglerMonitor,
    Watchdog,
    run_with_recovery,
)


# --------------------------------------------------------------------- optim
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] <= lrs[1]
    assert abs(lrs[-1] - 0.1) < 1e-6          # cosine floor


def test_grad_clip_effect():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, state2 = adamw_update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    # clipped: second moment bounded by clip^2
    assert float(state2.v["w"].max()) <= 1.0 * (1 - cfg.b2) + 1e-6


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    out, extra = ckpt.restore(str(tmp_path), tree)
    assert extra == {"note": "x"}
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_checkpoint_keep_k(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros((3, 2))})


def test_checkpoint_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"zz": jnp.zeros(2)})


# ---------------------------------------------------------------- resilience
def test_run_with_recovery_restores():
    injector = FailureInjector([3, 5])
    executed = []
    restores = []

    def step(s):
        injector.check(s)
        executed.append(s)

    def on_failure(s, e):
        restores.append(s)
        return max(s - 1, 0)   # "restore" one step back

    final = run_with_recovery(step, start_step=0, end_step=8,
                              on_failure=on_failure,
                              policy=RetryPolicy(backoff_s=0.0))
    assert final == 8
    assert restores == [3, 5]
    assert set(executed) == set(range(8))


def test_run_with_recovery_gives_up():
    def step(s):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_recovery(step, start_step=0, end_step=2,
                          on_failure=lambda s, e: s,
                          policy=RetryPolicy(max_restarts=2, backoff_s=0.0))


def test_watchdog_fires():
    import time
    with pytest.raises(Exception):
        with Watchdog(0.05):
            time.sleep(0.2)


def test_watchdog_passes_fast_step():
    with Watchdog(1.0):
        pass


def test_straggler_monitor():
    m = StragglerMonitor(decay=0.5, threshold=1.4)
    for _ in range(10):
        for h in ["h0", "h1", "h2", "h3"]:
            m.report(h, 1.0)
        m.report("slow", 2.5)
    assert m.stragglers() == ["slow"]


# --------------------------------------------------------------- compression
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, 128), jnp.float32)
    q, s = comp.quantize(x)
    err = np.abs(np.asarray(comp.dequantize(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_rounds():
    """Σ transmitted ≈ Σ inputs — EF carries quantization error forward."""
    rng = np.random.default_rng(0)
    tree = {"g": jnp.zeros(64)}
    ef = comp.ef_init(tree)
    total_in = np.zeros(64)
    total_tx = np.zeros(64)
    for i in range(50):
        g = {"g": jnp.asarray(rng.normal(0, 1, 64), jnp.float32)}
        total_in += np.asarray(g["g"])
        q, s, ef = comp.compress_tree(g, ef)
        total_tx += np.asarray(comp.decompress_tree(q, s)["g"])
    resid = np.abs(total_in - total_tx).max()
    # residual is bounded by one quantization step, not O(rounds)
    assert resid < 0.2


def test_compression_ratio():
    tree = {"g": jnp.zeros(1024)}
    raw, c = comp.compressed_mean_bytes(tree)
    assert raw == 4096 and c < raw / 3


# -------------------------------------------------------------------- elastic
@given(st.integers(1, 4096), st.sampled_from([4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_plan_mesh_properties(n, tp):
    plan = plan_mesh(n, tp)
    assert plan.size <= n
    assert plan.size >= 1
    assert plan.shape[-1] <= tp
    # mesh uses as many devices as divisibility allows with the chosen TP
    assert plan.size >= n // 2 or n < 4


def test_degrade_sequence():
    seq = degrade_sequence(512, 16, [16, 64, 200])
    sizes = [p.size for p in seq]
    assert sizes == sorted(sizes, reverse=True)
    # 496 and 432 devices both keep the requested TP=16
    assert all(p.shape[-1] == 16 for p in seq[:2])
    # an awkward survivor count (odd) degrades TP rather than dying
    odd = degrade_sequence(512, 16, [1])[0]
    assert odd.size >= 1 and odd.shape[-1] <= 16
