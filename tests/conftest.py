import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
_VENDOR = os.path.join(REPO, "tests", "_vendor")

try:  # the container image ships no `hypothesis`; fall back to the shim
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, _VENDOR)


def run_subprocess(code: str, n_devices: int = 1, timeout: int = 600):
    """Run python `code` in a fresh process with `n_devices` fake host
    devices (jax locks the device count at init, so multi-device tests must
    be subprocesses)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n_devices}")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.fixture
def subproc():
    return run_subprocess


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_distinct_negs(rng, tokens, vocab, n_neg):
    """Negatives satisfying the kernel's per-window distinctness invariant."""
    S, L = tokens.shape
    negs = np.zeros((S, L, n_neg), dtype=np.int32)
    for s in range(S):
        for t in range(L):
            c = rng.choice(vocab - 1, size=n_neg, replace=False)
            negs[s, t] = c + (c >= tokens[s, t])
    return negs
