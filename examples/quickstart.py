"""Quickstart: train FULL-W2V embeddings on a synthetic clustered corpus,
then inspect nearest neighbours and quality metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.w2v import smoke
from repro.core.quality import evaluate
from repro.core.trainer import TrainSession
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus


def main() -> None:
    cfg = smoke(epochs=8, dim=32)
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=800, mean_len=12, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    print(f"vocab={pipe.vocab.size} words/epoch={pipe.epoch_words}")

    # backend="auto" resolves against the kernel registry (jnp on CPU);
    # on_metrics streams per-batch progress
    trainer = TrainSession(
        pipe, cfg, backend="auto",
        on_metrics=lambda m: (m.batches_seen % 40 == 0) and print(
            f"  epoch {m.epoch} batch {m.batches_seen}: "
            f"{m.words_seen:,} words, lr={m.lr:.4f}"))
    trainer.train()
    print(f"throughput: {trainer.words_per_sec:,.0f} words/sec "
          f"(backend={trainer.backend})")

    # ground-truth clusters mapped through vocab ids
    inv = np.zeros(pipe.vocab.size, dtype=int)
    for w, i in pipe.vocab.ids.items():
        inv[i] = corpus.clusters[w]
    print("quality:", {k: round(v, 3)
                       for k, v in evaluate(trainer.embeddings(), inv).items()})

    for wid in (0, 20, 40):
        nn = trainer.nearest(wid, k=4)
        print(f"word {wid} (cluster {inv[wid]}) -> neighbours "
              f"{[(int(n), int(inv[n])) for n in nn]}")


if __name__ == "__main__":
    main()
