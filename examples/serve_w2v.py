"""Train a tiny corpus, serve it, and hot-swap a fresh checkpoint —
the full training-to-serving story (DESIGN.md §10) in one script.

1. Train FULL-W2V on a synthetic clustered corpus (vocab-sharded layout,
   1-shard on CPU) and publish a split checkpoint.
2. Stand up the snapshot watcher + batching server over the checkpoint
   directory and answer nearest-neighbour and analogy queries, checking
   every answer against the dense single-host oracle.
3. Train a little more, publish a new checkpoint, and watch the server
   pick it up without restarting (in-flight queries finish on the old
   snapshot; new ones see the new step).

    PYTHONPATH=src python examples/serve_w2v.py
"""
import tempfile

import numpy as np

from repro.configs.w2v import smoke
from repro.core.trainer import TrainSession
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus
from repro.serve import EmbeddingServer, SnapshotWatcher
from repro.serve.query import dense_topk


def check_parity(res, oracle, ids, k, mode):
    want_ids, want_sc = dense_topk(oracle, ids, k=k, mode=mode)
    ok = (np.array_equal(res.ids, want_ids)
          and np.allclose(res.scores, want_sc, atol=1e-5))
    assert ok, f"{mode} results diverge from the dense oracle"
    return want_ids


def main() -> None:
    cfg = smoke(epochs=4, dim=32, vocab_shard=True)
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=800, mean_len=12, seed=0)
    ckpt_dir = tempfile.mkdtemp(prefix="serve_w2v_")
    trainer = TrainSession(BatchingPipeline(corpus, cfg), cfg,
                           backend="auto", ckpt_dir=ckpt_dir)
    # stop short of the last epoch: the run continues (at a live learning
    # rate) after the server is up, for the hot-swap leg below
    trainer.train(max_batches=40)
    print("checkpoint:", trainer.save_checkpoint())

    with SnapshotWatcher(ckpt_dir, poll_s=0.05) as watcher:
        index = watcher.wait_ready()
        print(f"serving: step={index.step} vocab={index.vocab_size} "
              f"dim={index.dim} shards={index.n_shards}")
        with EmbeddingServer(watcher, batch_size=16, deadline_ms=1.0,
                             k=4) as server:
            oracle = index.dense_embeddings()

            # nearest neighbours: same-cluster words should dominate
            inv = np.zeros(index.vocab_size, dtype=int)
            for w, i in trainer.pipeline.vocab.ids.items():
                inv[i] = corpus.clusters[w]
            ids = np.array([0, 20, 40], np.int32)
            res = server.neighbors(ids)
            check_parity(res, oracle, ids, k=4, mode="nn")
            for q, row in zip(ids, res.ids):
                print(f"  word {q} (cluster {inv[q]}) -> neighbours "
                      f"{[(int(n), int(inv[n])) for n in row]}")
            print("oracle_parity=ok (nn)")

            # analogy a - b + c: clustermate of c expected near the top
            triples = np.array([[0, 1, 20], [20, 21, 40]], np.int32)
            res = server.analogy(triples)
            check_parity(res, oracle, triples, k=4, mode="analogy")
            print("oracle_parity=ok (analogy)")

            # --- hot-swap: publish a newer checkpoint mid-serving -------
            old_step = index.step
            old_res = server.neighbors(ids)
            trainer.train(max_batches=10)
            print("checkpoint:", trainer.save_checkpoint())
            import time
            deadline = time.monotonic() + 30.0
            while watcher.current().step == old_step:
                assert time.monotonic() < deadline, "swap not picked up"
                time.sleep(0.05)
            new_index = watcher.current()
            print(f"swap: step {old_step} -> {new_index.step} "
                  f"(server not restarted)")
            res = server.neighbors(ids)
            assert res.snapshot_step == new_index.step
            check_parity(res, new_index.dense_embeddings(), ids, k=4,
                         mode="nn")
            print("oracle_parity=ok (post-swap)")
            # ten more training batches move the scores (the ids of a
            # converged tiny model may legitimately hold steady)
            changed = not np.allclose(old_res.scores, res.scores)
            assert changed, "post-swap answers identical to pre-swap"
            print(f"answers_changed={changed} "
                  f"(served {server.served} queries, 0 dropped)")
    print("serve_w2v: ok")


if __name__ == "__main__":
    main()
