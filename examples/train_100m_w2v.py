"""End-to-end driver (paper's kind: embedding training): a ~100M-parameter
Word2Vec model — 400k vocabulary × d=128 × two tables — trained for a few
hundred batches with checkpointing and the full host batching pipeline.

    PYTHONPATH=src python examples/train_100m_w2v.py [--batches 200]
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.core.trainer import TrainSession
from repro.data.corpus import synthetic_zipf_corpus
from repro.data.prefetch import make_pipeline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=400_000)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--prefetch-workers", type=int, default=2,
                    help="async host batching workers (0 = synchronous)")
    args = ap.parse_args()

    cfg = W2VConfig(dim=128, window=5, negatives=5, epochs=1, min_count=1,
                    subsample_t=0.0, sentences_per_batch=512,
                    max_sentence_len=64,
                    prefetch_workers=args.prefetch_workers)
    print("building corpus...")
    corpus = synthetic_zipf_corpus(vocab_size=args.vocab,
                                   n_sentences=args.batches * 512,
                                   mean_len=24, zipf_a=1.1, seed=0)
    pipe = make_pipeline(corpus, cfg)   # async when prefetch_workers > 0
    n_params = 2 * pipe.vocab.size * cfg.dim
    print(f"vocab={pipe.vocab.size:,} params={n_params / 1e6:.1f}M")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "w2v_100m_ckpt")

    # TrainSession owns periodic checkpointing (atomic, pruned) and
    # resumes from the latest checkpoint in ckpt_dir automatically
    trainer = TrainSession(
        pipe, cfg, backend="jnp", ckpt_dir=ckpt_dir, ckpt_every=50,
        on_metrics=lambda m: (m.batches_seen % 50 == 0) and print(
            f"  batch {m.batches_seen}: {m.words_seen:,} words "
            f"(checkpointed)"))
    if trainer.resumed_step is not None:
        print(f"resumed from checkpoint batch {trainer.resumed_step}")
    t0 = time.time()
    trainer.train(max_batches=args.batches)
    print(f"trained {trainer.state.words_seen:,} words in "
          f"{time.time() - t0:.0f}s -> {trainer.words_per_sec:,.0f} words/s "
          f"(device busy {trainer.device_busy_frac:.0%})")
    print("final checkpoint:", trainer.save_checkpoint())
    emb = trainer.embeddings()
    print("embedding norms: mean", float(np.linalg.norm(emb, axis=1).mean()))


if __name__ == "__main__":
    main()
