"""Train a reduced assigned-architecture LM (any of the 10 configs) on
synthetic tokens with checkpointing + failure recovery — the LM half of
the framework end-to-end.

    PYTHONPATH=src python examples/lm_train_smoke.py --arch jamba-1.5-large-398b
"""
import argparse
import tempfile

from repro.configs import get_smoke, list_archs
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import AdamWConfig
from repro.train.resilience import FailureInjector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    ckpt_dir = tempfile.mkdtemp(prefix="lm_smoke_")
    loop = LoopConfig(steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=5,
                      log_every=5)
    opt = AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=2)
    injector = (FailureInjector([args.steps // 2])
                if args.inject_failure else None)
    trainer = Trainer(cfg, opt, loop, batch=4, seq=64,
                      failure_injector=injector)
    out = trainer.train()
    losses = out["losses"]
    print(f"{args.arch}: step {out['final_step']}, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {ckpt_dir})")


if __name__ == "__main__":
    main()
