"""Data-parallel Hogwild W2V (the paper's multi-GPU future-work, on a JAX
mesh): sentences shard over the `data` axis, each device runs the
FULL-W2V pass on its shard, table replicas are averaged every batch.
The mesh composes with window tiling (`tile_windows=4`): the host tile
schedule is per-sentence, so each device consumes exactly its shard's
`plan_tiles` rows. Re-executes itself with 4 fake host devices.

    PYTHONPATH=src python examples/distributed_w2v.py
"""
import os
import subprocess
import sys


def main() -> None:
    if os.environ.get("_W2V_DIST_CHILD") != "1":
        env = dict(os.environ)
        env["_W2V_DIST_CHILD"] = "1"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        sys.exit(subprocess.call([sys.executable, __file__], env=env))

    import jax
    import numpy as np

    from repro.configs.w2v import smoke
    from repro.core.quality import evaluate
    from repro.core.trainer import TrainSession
    from repro.data.batching import BatchingPipeline
    from repro.data.corpus import synthetic_cluster_corpus
    from repro.launch.mesh import make_host_mesh

    print("devices:", jax.device_count())
    # tile_windows=4: mesh sharding × window tiling compose (per-shard
    # tile plans; Hogwild pmean averaging unchanged)
    cfg = smoke(epochs=5, dim=32, sentences_per_batch=64, tile_windows=4)
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=800, mean_len=12, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    mesh = make_host_mesh(model=1)          # (data=4,)
    trainer = TrainSession(pipe, cfg, backend="jnp", mesh=mesh)
    print("backend:", trainer.backend)
    trainer.train()
    print(f"throughput: {trainer.words_per_sec:,.0f} words/s over "
          f"{mesh.devices.size} devices")
    inv = np.zeros(pipe.vocab.size, dtype=int)
    for w, i in pipe.vocab.ids.items():
        inv[i] = corpus.clusters[w]
    print("quality:", {k: round(v, 3)
                       for k, v in evaluate(trainer.embeddings(), inv).items()})


if __name__ == "__main__":
    main()
