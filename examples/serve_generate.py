"""Batched generation: prefill a prompt batch, then decode tokens
autoregressively with the KV/state cache — the serve-side end-to-end path
(works for every assigned arch family: attention, SSM, hybrid, MoE).

    PYTHONPATH=src python examples/serve_generate.py --arch mamba2-1.3b --steps 16
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke, list_archs
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    pref = None
    if cfg.prefix_len:
        pref = jnp.asarray(
            rng.normal(size=(args.batch, cfg.prefix_len, cfg.d_model)),
            jnp.float32)

    max_len = cfg.prefix_len + args.prompt_len + args.steps
    logits, cache, clen = lm.prefill(cfg, params, prompt, pref,
                                     cache_dtype=jnp.float32)
    # widen attention KV caches to generation capacity (mamba state
    # caches are fixed-size)
    cache = tuple(
        {k: (jnp.pad(v, [(0, 0), (0, 0), (0, max_len - v.shape[2]),
                         (0, 0), (0, 0)]) if k in ("k", "v") else v)
         for k, v in blk.items()}
        for blk in cache)

    decode = jax.jit(lambda p, c, ln, t: lm.decode_step(cfg, p, c, ln, t))
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(args.steps - 1):
        logits, cache = decode(params, cache, clen + i, tok)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits / args.temperature).astype(jnp.int32)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print(f"{args.arch}: generated {gen.shape} tokens")
    for b in range(args.batch):
        print(f"  seq{b}: {np.asarray(prompt[b])[-4:].tolist()} -> "
              f"{np.asarray(gen[b]).tolist()}")


if __name__ == "__main__":
    main()
