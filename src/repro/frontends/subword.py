"""fastText-style subword frontend: hashed n-gram bags per word.

Bojanowski et al. represent a word's input vector as the sum of its row
and the rows of its character n-grams, hashed into a fixed bucket table.
Here that is the engine's ``bags`` feature: ``prepare`` builds a
``(V, B)`` membership table from the vocabulary — member 0 is the word's
own row, the rest are ``vocab.size + (fnv1a(ngram) % buckets)``, -1
padded — and ``finalize_packed`` materializes ``Batch.bags`` per token
position. The kernels then *load* each center row as the masked
gather-sum of its members and *store* by scattering the row's delta to
every member (duplicated buckets accumulate — faithful fastText
semantics; see the buf0 delta mirror in ``kernels/ref.py``).

Bucket rows live past the vocabulary with zero counts: always in the
vocab-sharded cold tail (the bag members stress the request-exact
exchange and the mixed-precision int8 cold path — exactly the traffic
shape the tentpole wants), never drawn as negatives.

The n-gram hash is FNV-1a over the UTF-8 bytes of the ``<word>``-bounded
n-gram — deterministic across processes (no PYTHONHASHSEED exposure).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.frontends.registry import FrontendSpec, Workload, register

_FNV_OFFSET = 0x811C9DC5
_FNV_PRIME = 0x01000193


def fnv1a(data: bytes) -> int:
    """32-bit FNV-1a — the deterministic bucket hash."""
    h = _FNV_OFFSET
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & 0xFFFFFFFF
    return h


def word_ngrams(word: str, minn: int = 3, maxn: int = 5) -> List[str]:
    """Character n-grams of ``<word>`` (angle brackets mark boundaries,
    as in fastText — "<wh" and "he>" are distinct from interior "he")."""
    w = f"<{word}>"
    return [w[i:i + n]
            for n in range(minn, maxn + 1)
            for i in range(len(w) - n + 1)]


def ngram_bucket(ngram: str, buckets: int) -> int:
    """The hash bucket of one n-gram."""
    return fnv1a(ngram.encode("utf-8")) % buckets


def build_bag_table(vocab, buckets: int, minn: int = 3, maxn: int = 5,
                    max_members: int = 0) -> np.ndarray:
    """The ``(V, B)`` membership table for a built vocabulary: member 0 is
    the word row itself, members 1.. its n-gram buckets mapped past the
    vocabulary (``vocab.size + bucket``), -1 padded. ``max_members``
    truncates pathological long words (0 = no cap). Duplicate buckets
    within a word are kept — their updates accumulate, like fastText's."""
    V = vocab.size
    bags: List[List[int]] = []
    for w, i in sorted(vocab.ids.items(), key=lambda kv: kv[1]):
        grams = word_ngrams(str(w), minn, maxn)
        members = [i] + [V + ngram_bucket(g, buckets) for g in grams]
        if max_members:
            members = members[:max_members]
        bags.append(members)
    width = max(len(m) for m in bags) if bags else 1
    table = np.full((V, width), -1, dtype=np.int32)
    for i, members in enumerate(bags):
        table[i, :len(members)] = members
    return table


def _build(cfg: W2VConfig, *, vocab: int = 2048, clusters: int = 32,
           sentences: int = 8_000, mean_len: int = 20,
           buckets: int = 4096, minn: int = 3, maxn: int = 5,
           max_members: int = 0, seed: int = 0, **_ignored) -> Workload:
    from repro.data.corpus import synthetic_cluster_corpus
    corpus = synthetic_cluster_corpus(
        n_clusters=clusters, words_per_cluster=max(vocab // clusters, 1),
        n_sentences=sentences, mean_len=mean_len, seed=seed)
    cfg = dataclasses.replace(cfg, min_count=1)

    def prepare(pipeline) -> None:
        pipeline.extra_rows = buckets
        pipeline.bag_table = build_bag_table(
            pipeline.vocab, buckets, minn=minn, maxn=maxn,
            max_members=max_members)

    return Workload(name="subword", corpus=corpus, cfg=cfg,
                    features=("bags",), prepare=prepare)


register(FrontendSpec(
    name="subword",
    description="fastText bags: hashed char n-grams summed into the center",
    corpus="words → `<word>` n-gram buckets",
    features=("bags",),
    build=_build))
