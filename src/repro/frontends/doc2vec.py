"""doc2vec (PV-DM) frontend: a document id as an extra context row.

Le & Mikolov's distributed-memory paragraph vectors extend each context
window with a paragraph (document) row that is *always in window*. Here
that is the engine's ``static_ctx`` feature: the corpus carries a
per-sentence ``doc_ids`` list, the batching pipeline threads each
sentence's doc through as ``Batch.docs`` (already mapped into table-extra
space ``vocab.size + doc``), and the kernels append the doc row as one
more context row to every window of the sentence — loaded once per
sentence, written back once, bit-identically in the sequential and tiled
paths (``kernels/ref.py``).

Doc rows live past the vocabulary in the embedding table
(``pipeline.extra_rows = n_docs``) with zero occurrence counts, so under
vocab sharding they always stripe into the cold tail and ride the
request-exact exchange, and negative sampling (word unigrams) can never
draw them. Stream packing (``cfg.ignore_delimiters``) flushes at document
boundaries — no pseudo-sentence ever spans two documents.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.data.corpus import Corpus
from repro.frontends.registry import FrontendSpec, Workload, register


def document_corpus(n_docs: int = 64, sents_per_doc: int = 24,
                    n_clusters: int = 16, words_per_cluster: int = 32,
                    mean_len: int = 16, purity: float = 0.9,
                    seed: int = 0) -> Corpus:
    """Planted-topic *document* corpus: document d draws ~``purity`` of its
    words from cluster ``d % n_clusters``, so same-topic documents share
    vocabulary — a correct PV-DM run embeds their doc vectors nearby (and
    word vectors still cluster, so ``core.quality`` applies unchanged)."""
    rng = np.random.default_rng(seed)
    v = n_clusters * words_per_cluster
    clusters = np.repeat(np.arange(n_clusters), words_per_cluster)
    sentences: List[List[int]] = []
    doc_ids: List[int] = []
    for d in range(n_docs):
        c = d % n_clusters
        for _ in range(sents_per_doc):
            ln = max(4, rng.poisson(mean_len))
            in_cluster = rng.random(ln) < purity
            words = np.where(
                in_cluster,
                c * words_per_cluster + rng.integers(
                    0, words_per_cluster, ln),
                rng.integers(0, v, ln),
            )
            sentences.append(words.astype(np.int64).tolist())
            doc_ids.append(d)
    return Corpus(sentences=sentences, vocab_size=v, clusters=clusters,
                  doc_ids=doc_ids)


def _build(cfg: W2VConfig, *, docs: int = 64, sents_per_doc: int = 24,
           clusters: int = 16, words_per_cluster: int = 32,
           mean_len: int = 16, seed: int = 0, **_ignored) -> Workload:
    corpus = document_corpus(
        n_docs=docs, sents_per_doc=sents_per_doc, n_clusters=clusters,
        words_per_cluster=words_per_cluster, mean_len=mean_len, seed=seed)
    n_docs = int(max(corpus.doc_ids)) + 1
    # min_count=1: a dropped word would not shift doc ids, but tiny test
    # corpora should not silently lose vocabulary either
    cfg = dataclasses.replace(cfg, min_count=1)

    def prepare(pipeline) -> None:
        # one table row per document, appended past the vocabulary
        pipeline.extra_rows = n_docs

    return Workload(name="doc2vec", corpus=corpus, cfg=cfg,
                    features=("static_ctx",), prepare=prepare)


register(FrontendSpec(
    name="doc2vec",
    description="PV-DM: per-document row injected into every window",
    corpus="documents (sentences + doc ids)",
    features=("static_ctx",),
    build=_build))
