"""Workload frontends: corpus adapters + config presets over the W2V
engine (DESIGN.md §12) — node2vec/DeepWalk random walks, PV-DM doc2vec,
and fastText-style subword bags, all emitting the existing batch schema."""
from repro.frontends.registry import (FrontendSpec, Workload, get, names,
                                      register, specs)

__all__ = ["FrontendSpec", "Workload", "get", "names", "register", "specs"]
