"""Workload-frontend registry (DESIGN.md §12).

A *frontend* adapts a non-word2vec workload into the engine's existing
batch schema: it provides a corpus (sentences of integer "tokens" — words,
graph nodes, anything SGNS-shaped), a config preset, and optionally
frontend state the batching pipeline threads through to the kernels:

* ``features`` — the ``StepInputs`` extensions this workload's batches
  carry (``"static_ctx"`` for an always-in-window doc row, ``"bags"`` for
  per-token member bags). ``registry.resolve(frontends=...)`` gates
  backends on them, so a workload can never silently run on a kernel that
  ignores half its inputs.
* ``prepare(pipeline)`` — attaches table extras after the vocabulary is
  built: ``pipeline.extra_rows`` (doc rows / n-gram buckets appended at
  ``[vocab.size, table_rows)``) and ``pipeline.bag_table``.

Everything downstream — tiling, prefetch workers, vocab sharding, mixed
precision, checkpointing, serving — is untouched: a frontend's batches
are pure functions of ``(corpus, cfg, epoch, index)`` exactly like plain
w2v batches, so bit-determinism across worker counts is inherited, not
re-proven per workload.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.configs.w2v import W2VConfig
from repro.data.corpus import Corpus


@dataclasses.dataclass
class Workload:
    """One buildable workload: corpus + (possibly adjusted) config, plus
    the frontend state to attach to the batching pipeline."""
    name: str
    corpus: Corpus
    cfg: W2VConfig
    features: Tuple[str, ...] = ()
    # called with the constructed pipeline (vocabulary built) to attach
    # extra_rows / bag_table; None for pure corpus adapters
    prepare: Optional[Callable] = None

    def attach(self, pipeline) -> None:
        """Attach this workload's frontend state to a batching pipeline
        (idempotent; call once, right after pipeline construction)."""
        pipeline.frontend_features = self.features
        if self.prepare is not None:
            self.prepare(pipeline)


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    """Registry descriptor for one workload frontend.

    ``build(cfg, **knobs)`` returns a :class:`Workload`; every knob has a
    default so ``build(cfg)`` always works (CLI flags override). The
    ``description`` / ``corpus`` / ``features`` fields feed the generated
    README workload table (``tools/check_docs.py``).
    """
    name: str
    description: str      # one line, for the generated docs table
    corpus: str           # what the adapter consumes
    features: Tuple[str, ...]
    build: Callable[..., Workload]


_REGISTRY: Dict[str, FrontendSpec] = {}


def register(spec: FrontendSpec) -> FrontendSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"frontend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> FrontendSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown workload frontend {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name]


def names() -> Tuple[str, ...]:
    """Registered frontend names, ``w2v`` first (the default workload)."""
    _ensure_loaded()
    rest = sorted(n for n in _REGISTRY if n != "w2v")
    return ("w2v", *rest)


def specs() -> Tuple[FrontendSpec, ...]:
    """All registered specs in :func:`names` order (docs generation)."""
    return tuple(_REGISTRY[n] for n in names())


def _ensure_loaded() -> None:
    """Import the built-in frontend modules (each registers itself)."""
    from repro.frontends import doc2vec, node2vec, subword  # noqa: F401


# ---------------------------------------------------------------------------
# The identity frontend: plain FULL-W2V on the synthetic cluster corpus.
# ---------------------------------------------------------------------------

def _build_w2v(cfg: W2VConfig, *, vocab: int = 8192, clusters: int = 64,
               sentences: int = 20_000, mean_len: int = 24,
               seed: int = 0, **_ignored) -> Workload:
    from repro.data.corpus import synthetic_cluster_corpus
    corpus = synthetic_cluster_corpus(
        n_clusters=clusters,
        words_per_cluster=max(vocab // clusters, 1),
        n_sentences=sentences, mean_len=mean_len, seed=seed)
    return Workload(name="w2v", corpus=corpus, cfg=cfg)


register(FrontendSpec(
    name="w2v",
    description="FULL-W2V SGNS on words (the paper's workload)",
    corpus="planted-cluster sentences",
    features=(),
    build=_build_w2v))
