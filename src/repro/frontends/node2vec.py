"""node2vec / DeepWalk frontend: biased random walks over a graph.

Grover & Leskovec's node2vec is SGNS over node "sentences": walks sampled
from a graph with a second-order bias — from edge ``(prev, cur)``, the
next hop ``x`` is drawn from ``cur``'s neighbours with unnormalized weight

    1/p  if x == prev          (return)
    1    if x ~ prev           (stay close: x adjacent to prev)
    1/q  otherwise             (explore)

``p`` small → BFS-ish (structural roles), ``q`` small → DFS-ish
(communities); ``p = q = 1`` degenerates to DeepWalk's uniform walks.

The frontend is a *pure corpus adapter*: walks are generated host-side
with keyed randomness — walk ``i`` draws from
``SeedSequence([seed, _WALK_TAG, i])`` and nothing else — so the walk
corpus is a pure function of ``(graph, cfg.seed, knobs)``, and every
downstream guarantee (bit-determinism across prefetch worker counts,
vocab sharding, mixed precision) is inherited from the batching layer
unchanged, exactly like PR 4's batches. Per-epoch variation comes from
the pipeline's keyed subsample/negative streams, not from re-walking.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.data.corpus import Corpus
from repro.frontends.registry import FrontendSpec, Workload, register

# domain-separation tag for the per-walk rng keys (cf. data.batching's
# _SUBSAMPLE_TAG / _NEGATIVES_TAG)
_WALK_TAG = 0x4E32


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable CSR adjacency: ``indices[indptr[v]:indptr[v+1]]`` are
    node v's neighbours, sorted ascending (binary-searchable, so the
    "adjacent to prev" test in the walk bias is O(log deg))."""
    indptr: np.ndarray    # (n_nodes + 1,) int64
    indices: np.ndarray   # (n_edges,) int64, sorted within each row

    @property
    def n_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    @classmethod
    def from_edges(cls, edges: Sequence[Tuple[int, int]],
                   n_nodes: Optional[int] = None,
                   undirected: bool = True) -> "Graph":
        """Build from an edge list. Duplicate edges collapse; self-loops
        are kept (a legal node2vec input — the walk can revisit)."""
        e = np.asarray(list(edges), dtype=np.int64).reshape(-1, 2)
        if undirected and e.size:
            e = np.concatenate([e, e[:, ::-1]], axis=0)
        n = int(n_nodes if n_nodes is not None
                else (e.max() + 1 if e.size else 0))
        if e.size:
            e = np.unique(e, axis=0)
            if e.min() < 0 or e.max() >= n:
                raise ValueError(f"edge endpoint out of range [0, {n})")
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, e[:, 0] + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=e[:, 1].copy())


def community_graph(n_communities: int = 16, nodes_per: int = 24,
                    extra_edges: int = 0, seed: int = 0) -> Graph:
    """Ring-of-cliques community graph: ``n_communities`` cliques of
    ``nodes_per`` nodes, consecutive cliques bridged by one edge (node 0
    of each to node 0 of the next), plus ``extra_edges`` random
    inter-community edges. Ground truth for quality eval: node v belongs
    to community ``v // nodes_per`` — node2vec with small q must embed
    same-clique nodes nearby."""
    edges: List[Tuple[int, int]] = []
    n = n_communities * nodes_per
    for c in range(n_communities):
        base = c * nodes_per
        for i in range(nodes_per):
            for j in range(i + 1, nodes_per):
                edges.append((base + i, base + j))
        edges.append((base, ((c + 1) % n_communities) * nodes_per))
    rng = np.random.default_rng(seed)
    for _ in range(extra_edges):
        u, v = rng.integers(0, n, 2)
        if u != v:
            edges.append((int(u), int(v)))
    return Graph.from_edges(edges, n_nodes=n)


def node2vec_walk(graph: Graph, start: int, length: int,
                  p: float, q: float,
                  rng: np.random.Generator) -> List[int]:
    """One biased walk from ``start``. Ends early at a sink (no out-
    neighbours). Pure given the rng — the determinism tests key it."""
    walk = [int(start)]
    prev = -1
    cur = int(start)
    for _ in range(length - 1):
        nbrs = graph.neighbors(cur)
        if nbrs.size == 0:
            break
        if prev < 0:
            nxt = int(nbrs[rng.integers(nbrs.size)])
        else:
            prev_nbrs = graph.neighbors(prev)
            adj = np.isin(nbrs, prev_nbrs, assume_unique=False)
            w = np.where(nbrs == prev, 1.0 / p, np.where(adj, 1.0, 1.0 / q))
            cdf = np.cumsum(w)
            nxt = int(nbrs[np.searchsorted(cdf, rng.random() * cdf[-1],
                                           side="right").clip(0,
                                                              nbrs.size - 1)])
        walk.append(nxt)
        prev, cur = cur, nxt
    return walk


def walk_corpus(graph: Graph, walks_per_node: int = 10,
                walk_length: int = 40, p: float = 1.0, q: float = 1.0,
                seed: int = 0,
                clusters: Optional[np.ndarray] = None) -> Corpus:
    """The full walk corpus: ``walks_per_node`` walks from every node, walk
    ``i`` (global index, node-major) keyed by
    ``SeedSequence([seed, _WALK_TAG, i])`` — any subset of walks can be
    regenerated independently and identically."""
    if p <= 0 or q <= 0:
        raise ValueError(f"p and q must be positive, got p={p}, q={q}")
    sentences: List[List[int]] = []
    n = graph.n_nodes
    for v in range(n):
        for r in range(walks_per_node):
            i = v * walks_per_node + r
            rng = np.random.default_rng(
                np.random.SeedSequence([seed, _WALK_TAG, i]))
            sentences.append(node2vec_walk(graph, v, walk_length, p, q, rng))
    return Corpus(sentences=sentences, vocab_size=n, clusters=clusters)


def _build(cfg: W2VConfig, *, communities: int = 16, nodes_per: int = 24,
           walks_per_node: int = 10, walk_length: int = 40,
           p: float = 1.0, q: float = 0.5, graph: Optional[Graph] = None,
           seed: int = 0, **_ignored) -> Workload:
    if graph is None:
        graph = community_graph(communities, nodes_per, seed=seed)
        clusters = np.arange(graph.n_nodes) // nodes_per
    else:
        clusters = None
    corpus = walk_corpus(graph, walks_per_node=walks_per_node,
                         walk_length=walk_length, p=p, q=q,
                         seed=seed if seed else cfg.seed, clusters=clusters)
    # node "words" are uniform-ish in walk corpora — subsampling would only
    # delete signal, so the preset disables it (node2vec's own choice)
    cfg = dataclasses.replace(cfg, min_count=1, subsample_t=0.0)
    return Workload(name="node2vec", corpus=corpus, cfg=cfg)


register(FrontendSpec(
    name="node2vec",
    description="biased p/q random walks over a graph (DeepWalk at p=q=1)",
    corpus="edge-list graph → keyed walks",
    features=(),
    build=_build))
