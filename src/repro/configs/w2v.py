"""Word2Vec (FULL-W2V) hyperparameter config — the paper's own workload.

Defaults follow the paper's evaluation setup (§5.1): d=128, N=5, W=5
(=> fixed W_f = ceil(W/2) = 3), lr=0.025 linear decay, subsample t=1e-4,
min_count=5, max sentence length 1000, S=10k sentences per batch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class W2VConfig:
    dim: int = 128
    window: int = 5                 # W; the kernel uses fixed W_f = ceil(W/2)
    negatives: int = 5              # N
    lr: float = 0.025
    min_lr_frac: float = 1e-4       # linear decay floor (fraction of lr)
    epochs: int = 20
    min_count: int = 5
    subsample_t: float = 1e-4
    max_sentence_len: int = 1000
    sentences_per_batch: int = 10_000  # S (paper §4.2)
    ignore_delimiters: bool = False    # paper §4.1 stream-packing mode
    neg_table_size: int = 1 << 20
    tile_windows: int = 1              # T — windows fused per kernel step
                                       # (DESIGN.md §4; T=1 == sequential)
    tile_gemm_windows: int = 4         # G — windows per GEMM group inside a
                                       # tile (bounds value staleness)
    pad_len: int = 0                   # L — padded sentence length per batch
                                       # (jit shape reuse); 0 -> derived, see
                                       # `resolved_pad_len`
    prefetch_workers: int = 0          # host pipeline workers (0 = fully
                                       # synchronous batching, DESIGN.md §4.1)
    prefetch_depth: int = 2            # bounded queue: finalized batches in
                                       # flight ahead of the device step
    prefetch_mode: str = "thread"      # "thread" (GIL-releasing numpy
                                       # finalize) or "process" (python-heavy
                                       # encode workloads)
    vocab_shard: bool = False          # shard the cold vocabulary tail over
                                       # the mesh data axis; the Zipf-hot
                                       # head stays replicated (DESIGN.md §8)
    hot_vocab_frac: float = 0.0        # replicated head as a fraction of V;
                                       # 0 -> smallest prefix covering
                                       # VOCAB_HOT_COVERAGE (~90%) of corpus
                                       # occurrences
    tables: str = ""                   # table storage spec, e.g.
                                       # "hot=bf16:frac=0.1,cold=int8" —
                                       # parsed by kernels.tables.parse into
                                       # the session TableSpec (DESIGN.md
                                       # §11); "" -> f32 tables from the
                                       # legacy vocab_shard/hot_vocab_frac
                                       # knobs above
    seed: int = 0

    @property
    def fixed_window(self) -> int:
        """W_f = ceil(W/2) — FULL-W2V's fixed context width (§3.2)."""
        return (self.window + 1) // 2

    @property
    def resolved_pad_len(self) -> int:
        """The padded batch length the training session uses: ``pad_len``
        when set, else ``min(max_sentence_len, 1024)`` (the jit shape-reuse
        cap long sentences are chunked into)."""
        return self.pad_len if self.pad_len > 0 else min(
            self.max_sentence_len, 1024)


def resolve_gemm_windows(tile: int, gemm_windows: int = 0) -> int:
    """Resolve the G knob (windows per GEMM group, DESIGN.md §4): 0 means
    the default min(tile, 4); always clamped to the tile size. Single source
    of truth for kernel, oracle, cost model, and benchmarks."""
    g = gemm_windows if gemm_windows > 0 else min(tile, 4)
    return max(1, min(g, tile))


# Reduced config for CPU tests / examples.
def smoke(**kw) -> W2VConfig:
    base = dict(dim=32, window=3, negatives=3, epochs=1,
                min_count=1, sentences_per_batch=64, max_sentence_len=64,
                subsample_t=0.0)  # tiny corpora: every word is "frequent"
    base.update(kw)
    return W2VConfig(**base)
