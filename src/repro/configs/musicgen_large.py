"""musicgen-large — decoder-only over EnCodec tokens (audio backbone).

[arXiv:2306.05284; hf] 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings via ``prefix_embeds``; training operates on audio-codec tokens.
"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        prefix_len=64,  # precomputed conditioning frames (frontend stub)
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="musicgen-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        prefix_len=4,
    ),
)
