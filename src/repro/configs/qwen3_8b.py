"""qwen3-8b — dense, qk_norm, GQA kv=8.

[hf:Qwen/Qwen3-8B; hf] 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab=151936,
        qk_norm=True,
        head_dim=128,
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
    ),
)
