"""mamba2-1.3b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128. Mamba2 blocks have no separate MLP (d_ff=0): the SSD mixer is
the whole layer.
"""
from repro.configs.base import ArchConfig, SSMConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
        tie_embeddings=True,
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="mamba2-smoke",
        n_layers=2,
        d_model=64,
        vocab=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
    ),
)
