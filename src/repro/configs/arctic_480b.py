"""arctic-480b — 128 experts top-2 + dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 (per expert) vocab=32000.
"""
from repro.configs.base import ArchConfig, MoEConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab=32000,
        moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True,
                      dense_residual_ff=4864),
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="arctic-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoEConfig(num_experts=4, top_k=2, dense_residual=True,
                      dense_residual_ff=96, capacity_factor=4.0),
    ),
)
