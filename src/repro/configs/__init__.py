from repro.configs.base import (
    SHAPES,
    ArchConfig,
    InputShape,
    MoEConfig,
    SSMConfig,
    cells,
    get_arch,
    get_smoke,
    list_archs,
)
from repro.configs.w2v import W2VConfig

__all__ = [
    "SHAPES", "ArchConfig", "InputShape", "MoEConfig", "SSMConfig",
    "cells", "get_arch", "get_smoke", "list_archs", "W2VConfig",
]
