"""moonshot-v1-16b-a3b (Moonlight) — MoE 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840.
"""
from repro.configs.base import ArchConfig, MoEConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163840,
        moe=MoEConfig(num_experts=64, top_k=6),
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="moonshot-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=256,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0),
    ),
)
