"""deepseek-67b — dense llama-arch, GQA kv=8.

[arXiv:2401.02954; hf] 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=102400,
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="deepseek-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
    ),
)
