"""internvl2-76b — InternViT + InternLM2 (VLM backbone).

[arXiv:2404.16821; unverified] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. The InternViT frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings via ``prefix_embeds``.
"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        prefix_len=256,  # ViT patch embeddings (frontend stub)
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="internvl2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        prefix_len=8,
    ),
)
