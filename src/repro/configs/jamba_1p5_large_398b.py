"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Jamba places one attention layer per 8-layer block (index 4 per the paper's
figure) and applies MoE every other layer.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register, shrink

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        moe=MoEConfig(num_experts=16, top_k=2),
        moe_every=2,
        hybrid_pattern=_PATTERN,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="jamba-smoke",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
    ),
)
