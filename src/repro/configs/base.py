"""Configuration system: architecture configs, input shapes, registry.

Every assigned architecture is a selectable config (``--arch <id>``); each
config file under ``repro/configs`` registers a full-size config (exercised
only via the dry-run — ShapeDtypeStruct, no allocation) and a reduced
``smoke()`` variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Snowflake-Arctic style dense residual MLP alongside the MoE branch.
    dense_residual: bool = False
    dense_residual_ff: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A decoder-style LM backbone configuration."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid layout: repeating block pattern, e.g. Jamba 1:7 attn:mamba.
    # Entries: "attn" | "mamba". Empty -> all-attn (or all-mamba for ssm).
    hybrid_pattern: Tuple[str, ...] = ()
    # MoE interleave: apply MoE FFN every `moe_every` layers (1 = all).
    moe_every: int = 1
    tie_embeddings: bool = False
    # vlm/audio modality stub: number of precomputed frontend embeddings
    # prepended to the token sequence (0 = none).
    prefix_len: int = 0
    norm_eps: float = 1e-5
    # --- scaling / perf knobs (not architecture identity) ---
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)
    # force bf16 outputs on row-parallel projections so TP partial sums
    # all-reduce in bf16 instead of XLA's f32 accumulators (halves the
    # dominant stream-collective wire bytes; perf variant)
    bf16_reduce: bool = False
    scan_layers: bool = True       # False -> python-unrolled (exact HLO cost)
    pipeline_stages: int = 1       # documented extension point (pod axis = DP)

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def layer_kinds(self) -> List[str]:
        """Per-layer mixer kind for the full depth."""
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.hybrid_pattern:
            pat = list(self.hybrid_pattern)
            assert self.n_layers % len(pat) == 0, (self.name, self.n_layers, len(pat))
            return pat * (self.n_layers // len(pat))
        return ["attn"] * self.n_layers

    def is_attention_free(self) -> bool:
        return all(k == "mamba" for k in self.layer_kinds())

    def supports_long_context(self) -> bool:
        """Sub-quadratic context: pure SSM or hybrid (sparse attention layers
        use the seq-sharded decode path)."""
        kinds = self.layer_kinds()
        return ("mamba" in kinds)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS roofline terms)."""
        d = self.d_model
        hd = self.resolved_head_dim()
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb + d  # final norm
        for i, kind in enumerate(self.layer_kinds()):
            total += d  # pre-mixer norm
            if kind == "attn":
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qk_norm:
                    total += 2 * hd
            else:
                s = self.ssm or SSMConfig()
                di = s.d_inner(d)
                nh = s.n_heads(d)
                # in_proj -> (z, x, B, C, dt), conv over (x, B, C), out_proj
                conv_ch = di + 2 * s.n_groups * s.d_state
                total += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                total += conv_ch * (s.d_conv + 1)   # conv weights + biases
                total += nh * 3          # A_log, D, dt_bias
                total += di              # gated norm
                total += di * d
            if self.d_ff:
                total += d  # pre-ffn norm
                ffn = 3 * d * self.d_ff  # SwiGLU
                if self.moe is not None and i % self.moe_every == 0:
                    total += d * self.moe.num_experts  # router
                    total += ffn * self.moe.num_experts
                    if self.moe.dense_residual:
                        total += 3 * d * self.moe.dense_residual_ff
                else:
                    total += ffn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full_ffn = 3 * d * self.d_ff
        per_layer_saving = full_ffn * (self.moe.num_experts - self.moe.top_k)
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.d_ff and i % self.moe_every == 0
        )
        return self.param_count() - n_moe_layers * per_layer_saving


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

_ARCH_MODULES = [
    "mamba2_1p3b",
    "moonshot_v1_16b_a3b",
    "arctic_480b",
    "starcoder2_3b",
    "deepseek_67b",
    "phi3_medium_14b",
    "qwen3_8b",
    "musicgen_large",
    "jamba_1p5_large_398b",
    "internvl2_76b",
]

_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ArchConfig
    smoke: Callable[[], ArchConfig]


def register(config: ArchConfig, smoke: Callable[[], ArchConfig]) -> ArchConfig:
    _REGISTRY[config.name] = ArchEntry(config=config, smoke=smoke)
    return config


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchConfig:
    _load_all()
    return _REGISTRY[name].config


def get_smoke(name: str) -> ArchConfig:
    _load_all()
    return _REGISTRY[name].smoke()


def list_archs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


def cells(include_skips: bool = False) -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skips."""
    out = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context():
                if include_skips:
                    out.append((arch, shape))
                continue
            out.append((arch, shape))
    return out


def shrink(cfg: ArchConfig, **kw) -> ArchConfig:
    """Build a reduced same-family smoke config."""
    return dataclasses.replace(cfg, **kw)
