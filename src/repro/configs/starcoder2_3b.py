"""starcoder2-3b — dense, GQA kv=2, RoPE.

[arXiv:2402.19173; hf] 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab=49152,
    ),
    smoke=lambda: shrink(
        CONFIG,
        name="starcoder2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
    ),
)
