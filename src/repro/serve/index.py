"""Serving-side embedding index: checkpoint bytes → per-shard device buffers.

:class:`EmbeddingIndex` is the read-only counterpart of the trainer's
split table state (DESIGN.md §8): the replicated Zipf-hot head plus the
striped cold tail, pre-normalized row-wise on device so every query is a
pure dot-product scan. Loading goes through ``checkpoint.peek`` +
``checkpoint.restore`` and touches **only the input table** (``hot_in``/
``cold_in`` — never the output table, never a merged ``(V, d)``
reassembly): a split checkpoint restores leaf-by-leaf into the serving
layout, re-striping the cold table host-side when the serving shard
count differs from the writing run's (a permutation of the cold rows,
O(cold·d) — the full-table merge path is deliberately never taken).

Every index carries a placement — a 1-shard placement when serving on
one device — so the query path (:mod:`repro.serve.query`) is always the
sharded code, exactly like the trainer's vocab-sharded step.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.vocab_placement import VocabPlacement

log = logging.getLogger("repro.serve.index")

# Hot-head fraction used when a *replicated* checkpoint (no recorded
# placement) is split for serving: the vocabulary is frequency-sorted by
# construction, so a prefix head is still the Zipf-hot set even without
# the original corpus counts.
SERVE_HOT_FRAC = 0.1


def _normalize(rows: jax.Array) -> jax.Array:
    """L2-normalize rows (zero/padding rows stay zero)."""
    norm = jnp.linalg.norm(rows, axis=-1, keepdims=True)
    return rows / jnp.maximum(norm, 1e-12)


def _restripe(cold: np.ndarray, src: VocabPlacement,
              dst: VocabPlacement) -> np.ndarray:
    """Permute a shard-major cold table from ``src``'s stripe layout to
    ``dst``'s — the elastic-serving path (train on N shards, serve on M)
    without reassembling the full table."""
    out = np.zeros((dst.cold_pad,) + cold.shape[1:], cold.dtype)
    out[dst._perm()[:dst.cold]] = cold[src._perm()[:src.cold]]
    return out


@dataclasses.dataclass
class EmbeddingIndex:
    """Pre-normalized, shard-resident input-embedding table + its layout.

    ``hot`` is the replicated normalized head ``(hot, d)``; ``cold`` the
    shard-major normalized cold table ``(cold_pad, d)`` (rows over the
    mesh ``data`` axis when a real mesh is attached). ``step`` records
    which checkpoint step the index was built from — the snapshot
    identity the hot-swap protocol flips on.
    """

    placement: VocabPlacement
    hot: jax.Array                  # (hot, d) f32, rows L2-normalized
    cold: jax.Array                 # (cold_pad, d) f32, rows L2-normalized
    mesh: Mesh
    step: Optional[int] = None
    extra: Dict = dataclasses.field(default_factory=dict)

    @property
    def vocab_size(self) -> int:
        """V — real vocabulary rows served."""
        return self.placement.vocab_size

    @property
    def dim(self) -> int:
        """d — embedding width."""
        return int(self.hot.shape[1])

    @property
    def n_shards(self) -> int:
        """Serving shard count (the mesh ``data`` axis)."""
        return self.placement.n_shards

    # -- construction --------------------------------------------------------
    @classmethod
    def load(cls, ckpt_dir: str, step: Optional[int] = None,
             mesh: Optional[Mesh] = None,
             hot_frac: float = SERVE_HOT_FRAC) -> "EmbeddingIndex":
        """Build an index from a checkpoint directory.

        ``peek`` decides the format: a split-table checkpoint restores
        ``hot_in``/``cold_in`` directly (re-striped if the serving shard
        count differs from the writing run's); a replicated checkpoint
        restores ``w_in`` and splits it under a prefix-head placement
        (``hot_frac``). Raises ``FileNotFoundError`` with no usable
        checkpoint and ``CorruptCheckpoint``/``KeyError`` per the
        checkpoint layer's contract — the snapshot watcher catches these
        and keeps serving the previous snapshot.
        """
        from repro.train import checkpoint as ckpt

        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        leaves, extra = ckpt.peek(ckpt_dir, step=step)
        mesh = mesh or Mesh(np.array(jax.devices()[:1]), ("data",))
        n_serve = int(mesh.shape["data"])

        def like(name):
            meta = leaves[name]
            return jax.ShapeDtypeStruct(meta["shape"], np.dtype(meta["dtype"]))

        if "hot_in" in leaves:
            src = VocabPlacement.from_extra(extra["vocab_shard"])
            tree, _ = ckpt.restore(
                ckpt_dir, {"hot_in": like("hot_in"), "cold_in": like("cold_in")},
                step=step)
            hot = np.asarray(tree["hot_in"], np.float32)
            cold = np.asarray(tree["cold_in"], np.float32)
            placement = src
            if n_serve != src.n_shards:
                placement = VocabPlacement(vocab_size=src.vocab_size,
                                           hot=src.hot, n_shards=n_serve)
                cold = _restripe(cold, src, placement)
        else:
            tree, _ = ckpt.restore(ckpt_dir, {"w_in": like("w_in")}, step=step)
            full = np.asarray(tree["w_in"], np.float32)
            v = full.shape[0]
            placement = VocabPlacement(
                vocab_size=v, hot=max(1, min(int(round(hot_frac * v)), v - 1)),
                n_shards=n_serve)
            hot, cold = placement.split(full)
        return cls._stage(placement, hot, cold, mesh, step=step, extra=extra)

    @classmethod
    def from_session(cls, session,
                     mesh: Optional[Mesh] = None,
                     hot_frac: float = SERVE_HOT_FRAC) -> "EmbeddingIndex":
        """Index the live tables of a :class:`TrainSession` through its
        shard-aware accessor (``embeddings_sharded`` — no ``(V, d)``
        gather for sharded sessions)."""
        hot, cold, placement = session.embeddings_sharded()
        mesh = mesh or session.mesh or Mesh(np.array(jax.devices()[:1]),
                                            ("data",))
        if placement is None:
            full = np.asarray(hot, np.float32)
            v = full.shape[0]
            placement = VocabPlacement(
                vocab_size=v, hot=max(1, min(int(round(hot_frac * v)), v - 1)),
                n_shards=int(mesh.shape["data"]))
            hot, cold = placement.split(full)
        return cls._stage(placement, np.asarray(hot), np.asarray(cold), mesh,
                          step=session.state.batches_seen)

    @classmethod
    def _stage(cls, placement: VocabPlacement, hot: np.ndarray,
               cold: np.ndarray, mesh: Mesh, step: Optional[int] = None,
               extra: Optional[Dict] = None) -> "EmbeddingIndex":
        """Place + normalize the split tables on device (the staging half
        of a hot swap: the new snapshot is fully resident before the
        serving pointer flips)."""
        from repro.distributed.sharding import vocab_shard_sharding

        hot_dev = _normalize(jnp.asarray(hot, jnp.float32))
        cold_dev = jnp.asarray(cold, jnp.float32)
        if int(mesh.shape["data"]) > 1:
            cold_dev = jax.device_put(
                cold_dev, vocab_shard_sharding(mesh, cold.shape[0]))
        cold_dev = _normalize(cold_dev)
        jax.block_until_ready((hot_dev, cold_dev))   # staged, not lazy
        return cls(placement=placement, hot=hot_dev, cold=cold_dev,
                   mesh=mesh, step=step, extra=dict(extra or {}))

    # -- oracle access -------------------------------------------------------
    def dense_embeddings(self) -> np.ndarray:
        """The merged normalized ``(V, d)`` table — **oracle/test path
        only** (parity reference for :func:`repro.serve.query.dense_topk`);
        the serving path never materializes this."""
        return self.placement.merge(np.asarray(self.hot),
                                    np.asarray(self.cold))
