"""Serving-side embedding index: checkpoint bytes → per-shard device buffers.

:class:`EmbeddingIndex` is the read-only counterpart of the trainer's
split table state (DESIGN.md §8): the replicated Zipf-hot head plus the
striped cold tail, pre-normalized row-wise on device so every query is a
pure dot-product scan. Loading goes through ``checkpoint.peek`` +
``checkpoint.restore`` and touches **only the input table** (``hot_in``/
``cold_in`` — never the output table, never a merged ``(V, d)``
reassembly): a split checkpoint restores leaf-by-leaf into the serving
layout, re-striping the cold table host-side when the serving shard
count differs from the writing run's (a permutation of the cold rows,
O(cold·d) — the full-table merge path is deliberately never taken).
Storage dtypes come from the checkpoint *manifest*: quantized tables
(DESIGN.md §11) restore and re-stripe in storage precision — int8 rows
with their per-row scales riding the same permutation — and dequantize
exactly once when the snapshot stages onto the device.

Every index carries a placement — a 1-shard placement when serving on
one device — so the query path (:mod:`repro.serve.query`) is always the
sharded code, exactly like the trainer's vocab-sharded step.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.vocab_placement import VocabPlacement

log = logging.getLogger("repro.serve.index")

# Hot-head fraction used when a *replicated* checkpoint (no recorded
# placement) is split for serving: the vocabulary is frequency-sorted by
# construction, so a prefix head is still the Zipf-hot set even without
# the original corpus counts.
SERVE_HOT_FRAC = 0.1


def _normalize(rows: jax.Array) -> jax.Array:
    """L2-normalize rows (zero/padding rows stay zero)."""
    norm = jnp.linalg.norm(rows, axis=-1, keepdims=True)
    return rows / jnp.maximum(norm, 1e-12)


def _restripe(cold: np.ndarray, src: VocabPlacement,
              dst: VocabPlacement) -> np.ndarray:
    """Permute a shard-major cold table from ``src``'s stripe layout to
    ``dst``'s — the elastic-serving path (train on N shards, serve on M)
    without reassembling the full table."""
    out = np.zeros((dst.cold_pad,) + cold.shape[1:], cold.dtype)
    out[dst._perm()[:dst.cold]] = cold[src._perm()[:src.cold]]
    return out


@dataclasses.dataclass
class EmbeddingIndex:
    """Pre-normalized, shard-resident input-embedding table + its layout.

    ``hot`` is the replicated normalized head ``(hot, d)``; ``cold`` the
    shard-major normalized cold table ``(cold_pad, d)`` (rows over the
    mesh ``data`` axis when a real mesh is attached). ``step`` records
    which checkpoint step the index was built from — the snapshot
    identity the hot-swap protocol flips on.
    """

    placement: VocabPlacement
    hot: jax.Array                  # (hot, d) f32, rows L2-normalized
    cold: jax.Array                 # (cold_pad, d) f32, rows L2-normalized
    mesh: Mesh
    step: Optional[int] = None
    extra: Dict = dataclasses.field(default_factory=dict)

    @property
    def vocab_size(self) -> int:
        """V — real vocabulary rows served."""
        return self.placement.vocab_size

    @property
    def dim(self) -> int:
        """d — embedding width."""
        return int(self.hot.shape[1])

    @property
    def n_shards(self) -> int:
        """Serving shard count (the mesh ``data`` axis)."""
        return self.placement.n_shards

    # -- construction --------------------------------------------------------
    @classmethod
    def load(cls, ckpt_dir: str, step: Optional[int] = None,
             mesh: Optional[Mesh] = None,
             hot_frac: float = SERVE_HOT_FRAC) -> "EmbeddingIndex":
        """Build an index from a checkpoint directory.

        ``peek`` decides the format: a split-table checkpoint restores
        ``hot_in``/``cold_in`` directly (re-striped if the serving shard
        count differs from the writing run's); a replicated checkpoint
        restores ``w_in`` and splits it under a prefix-head placement
        (``hot_frac``). Raises ``FileNotFoundError`` with no usable
        checkpoint and ``CorruptCheckpoint``/``KeyError`` per the
        checkpoint layer's contract — the snapshot watcher catches these
        and keeps serving the previous snapshot.
        """
        from repro.train import checkpoint as ckpt

        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        leaves, extra = ckpt.peek(ckpt_dir, step=step)
        mesh = mesh or Mesh(np.array(jax.devices()[:1]), ("data",))
        n_serve = int(mesh.shape["data"])

        def like(name):
            meta = leaves[name]
            # the manifest is the dtype authority: quantized (int8/bf16)
            # checkpoints restore in their storage dtype, never assumed f32
            return jax.ShapeDtypeStruct(meta["shape"],
                                        ckpt.np_dtype(meta["dtype"]))

        scale = None
        if "hot_in" in leaves:
            src = VocabPlacement.from_extra(extra["vocab_shard"])
            names = ["hot_in", "cold_in"]
            if "scale_in" in leaves:     # int8 cold tail: per-row scales
                names.append("scale_in")
            tree, _ = ckpt.restore(ckpt_dir, {n: like(n) for n in names},
                                   step=step)
            # keep the *storage* dtypes through the re-stripe — the
            # destination buffer takes its dtype from the manifest, not
            # from whatever a previously-loaded shard array happened to
            # be. An int8 cold table re-stripes as int8, its scales
            # following the same row permutation, and dequantizes once at
            # the staging step below.
            hot = np.asarray(tree["hot_in"])
            cold = np.asarray(tree["cold_in"])
            if "scale_in" in tree:
                scale = np.asarray(tree["scale_in"])
            placement = src
            if n_serve != src.n_shards:
                placement = VocabPlacement(vocab_size=src.vocab_size,
                                           hot=src.hot, n_shards=n_serve)
                cold = _restripe(cold, src, placement)
                if scale is not None:
                    scale = _restripe(scale, src, placement)
        else:
            tree, _ = ckpt.restore(ckpt_dir, {"w_in": like("w_in")}, step=step)
            full = np.asarray(tree["w_in"]).astype(np.float32)  # bf16 ckpts
            v = full.shape[0]
            placement = VocabPlacement(
                vocab_size=v, hot=max(1, min(int(round(hot_frac * v)), v - 1)),
                n_shards=n_serve)
            hot, cold = placement.split(full)
        return cls._stage(placement, hot, cold, mesh, step=step, extra=extra,
                          scale=scale)

    @classmethod
    def from_session(cls, session,
                     mesh: Optional[Mesh] = None,
                     hot_frac: float = SERVE_HOT_FRAC) -> "EmbeddingIndex":
        """Index the live tables of a :class:`TrainSession` through its
        shard-aware accessor (``embeddings_sharded`` — no ``(V, d)``
        gather for sharded sessions)."""
        hot, cold, placement = session.embeddings_sharded()
        mesh = mesh or session.mesh or Mesh(np.array(jax.devices()[:1]),
                                            ("data",))
        if placement is None:
            full = np.asarray(hot, np.float32)
            v = full.shape[0]
            placement = VocabPlacement(
                vocab_size=v, hot=max(1, min(int(round(hot_frac * v)), v - 1)),
                n_shards=int(mesh.shape["data"]))
            hot, cold = placement.split(full)
        return cls._stage(placement, np.asarray(hot), np.asarray(cold), mesh,
                          step=session.state.batches_seen)

    @classmethod
    def _stage(cls, placement: VocabPlacement, hot: np.ndarray,
               cold: np.ndarray, mesh: Mesh, step: Optional[int] = None,
               extra: Optional[Dict] = None,
               scale: Optional[np.ndarray] = None) -> "EmbeddingIndex":
        """Place + normalize the split tables on device (the staging half
        of a hot swap: the new snapshot is fully resident before the
        serving pointer flips). Quantized tables arrive in storage dtype
        (int8 cold rows with their per-row ``scale``, or bf16) and
        dequantize exactly once here — after the device transfer, so the
        host→device copy moves the small quantized bytes, and elementwise
        decode preserves the cold sharding."""
        from repro.distributed.sharding import vocab_shard_sharding
        from repro.kernels import quant

        hot_dev = _normalize(jnp.asarray(hot, jnp.float32))
        cold_dev = jnp.asarray(cold)
        sharded = int(mesh.shape["data"]) > 1
        if sharded:
            cold_dev = jax.device_put(
                cold_dev, vocab_shard_sharding(mesh, cold.shape[0]))
        if scale is not None:
            scale_dev = jnp.asarray(scale)
            if sharded:
                scale_dev = jax.device_put(
                    scale_dev, vocab_shard_sharding(mesh, cold.shape[0]))
            cold_dev = quant.int8_decode(cold_dev, scale_dev)
        elif cold_dev.dtype != jnp.float32:
            cold_dev = cold_dev.astype(jnp.float32)
        cold_dev = _normalize(cold_dev)
        jax.block_until_ready((hot_dev, cold_dev))   # staged, not lazy
        return cls(placement=placement, hot=hot_dev, cold=cold_dev,
                   mesh=mesh, step=step, extra=dict(extra or {}))

    # -- oracle access -------------------------------------------------------
    def dense_embeddings(self) -> np.ndarray:
        """The merged normalized ``(V, d)`` table — **oracle/test path
        only** (parity reference for :func:`repro.serve.query.dense_topk`);
        the serving path never materializes this."""
        return self.placement.merge(np.asarray(self.hot),
                                    np.asarray(self.cold))
