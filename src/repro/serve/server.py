"""Request batcher in front of the jitted sharded top-k.

:class:`EmbeddingServer` coalesces individual neighbour/analogy requests
into fixed-size padded batches — the serving analogue of the training
kernel's minibatching: one device dispatch amortizes the table sweep
over the whole batch, and a *fixed* batch shape means the jitted
:func:`~repro.serve.query.make_topk_fn` compiles once per
``(placement, mode, k)`` and never again.

Batch-cut policy (DESIGN.md §10): a batch closes when it reaches
``batch_size`` query rows **or** ``deadline_ms`` after its first request
arrived, whichever comes first — bounded latency under light traffic,
full batches under heavy. Requests of different kinds (nn vs analogy)
never share a device call; a kind change closes the batch and the odd
request carries into the next one.

Snapshot discipline: the dispatcher takes **one** index reference per
batch, so every query in a batch is answered from a single coherent
snapshot even while :class:`~repro.serve.snapshot.SnapshotWatcher` flips
the pointer underneath. Each result records ``snapshot_step`` — the
chaos harness's torn-query check recomputes the oracle for that exact
step.

``close()`` drains the queue before the dispatcher exits: a request
accepted by :meth:`submit` is always answered (zero dropped queries);
requests arriving *after* close raise immediately instead of hanging.
"""
from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.index import EmbeddingIndex
from repro.serve.query import make_topk_fn

log = logging.getLogger("repro.serve.server")


@dataclasses.dataclass
class QueryResult:
    """One answered request: global-id/score top-k plus provenance."""

    ids: np.ndarray                 # (n, k) int32 global vocabulary ids
    scores: np.ndarray              # (n, k) f32 cosine scores
    snapshot_step: Optional[int]    # checkpoint step that answered it
    latency_us: float               # submit -> resolve wall time


class _Request:
    __slots__ = ("kind", "ids", "k", "t0", "event", "result", "error")

    def __init__(self, kind: str, ids: np.ndarray, k: int):
        self.kind = kind
        self.ids = ids
        self.k = k
        self.t0 = time.perf_counter()
        self.event = threading.Event()
        self.result: Optional[QueryResult] = None
        self.error: Optional[BaseException] = None

    def resolve(self, result: QueryResult) -> None:
        self.result = result
        self.event.set()

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self.event.set()

    def wait(self, timeout: Optional[float]) -> QueryResult:
        if not self.event.wait(timeout):
            raise TimeoutError("query not answered in time")
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result


class EmbeddingServer:
    """Deadline/max-batch query coalescer over a (possibly hot-swapped)
    :class:`EmbeddingIndex`.

    Parameters
    ----------
    source : an :class:`EmbeddingIndex` (static snapshot) or anything
        with a ``current() -> EmbeddingIndex`` method (a
        :class:`~repro.serve.snapshot.SnapshotWatcher` for live serving).
    batch_size : padded device batch — also the per-request row cap.
    deadline_ms : max time the first request in a batch waits for
        co-riders before the batch is cut short.
    k : neighbours returned per query (fixed per server: one compiled
        kernel per mode).
    """

    def __init__(self, source, batch_size: int = 32,
                 deadline_ms: float = 2.0, k: int = 5):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self._source = source
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_ms) / 1e3
        self.k = int(k)
        self._queue: "queue.Queue[_Request]" = queue.Queue()
        self._carry: Optional[_Request] = None
        self._fns: Dict[Tuple, object] = {}   # (placement, mode) -> jitted fn
        self._closed = False
        self._lock = threading.Lock()
        self.served = 0
        self.batches = 0
        self.latencies_us: List[float] = []
        self._thread = threading.Thread(
            target=self._dispatch_loop, name="embedding-server", daemon=True)
        self._thread.start()

    # -- public API ----------------------------------------------------------
    def current_index(self) -> EmbeddingIndex:
        """The snapshot the *next* batch would be served from."""
        if isinstance(self._source, EmbeddingIndex):
            return self._source
        return self._source.current()

    def submit(self, kind: str, ids, k: Optional[int] = None) -> _Request:
        """Enqueue a request; returns a waitable handle. ``ids`` is
        ``(n,)`` for ``kind="nn"``, ``(n, 3)`` rows ``(a, b, c)`` for
        ``kind="analogy"``; ``n <= batch_size``."""
        if kind not in ("nn", "analogy"):
            raise ValueError(f"unknown query kind {kind!r} (nn | analogy)")
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if kind == "analogy":
            ids = ids.reshape(-1, 3)
        n = ids.shape[0]
        if n < 1 or n > self.batch_size:
            raise ValueError(
                f"request has {n} queries; allowed 1..{self.batch_size}")
        k = self.k if k is None else int(k)
        if k > self.k:
            raise ValueError(f"k={k} exceeds server k={self.k}")
        req = _Request(kind, ids, k)
        with self._lock:
            if self._closed:
                raise RuntimeError("server is closed")
            self._queue.put(req)
        return req

    def neighbors(self, ids, k: Optional[int] = None,
                  timeout: float = 60.0) -> QueryResult:
        """Synchronous nearest-neighbour query for global ids ``(n,)``."""
        return self.submit("nn", ids, k=k).wait(timeout)

    def analogy(self, triples, k: Optional[int] = None,
                timeout: float = 60.0) -> QueryResult:
        """Synchronous ``a − b + c`` analogy query for rows ``(n, 3)``."""
        return self.submit("analogy", triples, k=k).wait(timeout)

    def close(self, timeout: float = 30.0) -> None:
        """Stop accepting requests, answer everything already accepted,
        then stop the dispatcher — zero dropped queries by construction."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "EmbeddingServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatcher ----------------------------------------------------------
    def _take_first(self) -> Optional[_Request]:
        if self._carry is not None:
            first, self._carry = self._carry, None
            return first
        try:
            return self._queue.get(timeout=0.01)
        except queue.Empty:
            return None

    def _collect_batch(self) -> Optional[List[_Request]]:
        """Block for a first request, then co-batch same-kind arrivals
        until the row budget or the deadline runs out."""
        first = self._take_first()
        if first is None:
            return None
        batch, rows = [first], first.ids.shape[0]
        deadline = first.t0 + self.deadline_s
        while rows < self.batch_size:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if (nxt.kind != first.kind
                    or rows + nxt.ids.shape[0] > self.batch_size):
                self._carry = nxt          # rides the next batch
                break
            batch.append(nxt)
            rows += nxt.ids.shape[0]
        return batch

    def _fn_for(self, index: EmbeddingIndex, mode: str):
        key = (index.placement, mode, self.k, self.batch_size)
        fn = self._fns.get(key)
        if fn is None:
            fn = make_topk_fn(index.placement, index.mesh, mode=mode,
                              k=self.k)
            self._fns[key] = fn
        return fn

    def _serve_batch(self, batch: List[_Request]) -> None:
        index = self.current_index()       # ONE snapshot for the batch
        kind = batch[0].kind
        ids = np.concatenate([r.ids for r in batch], axis=0)
        n = ids.shape[0]
        pad = self.batch_size - n
        if pad:                            # fixed shape: compile once
            fill = np.zeros((pad,) + ids.shape[1:], np.int32)
            ids = np.concatenate([ids, fill], axis=0)
        fn = self._fn_for(index, kind)
        out_ids, out_scores = fn(index.hot, index.cold, ids)
        out_ids = np.asarray(out_ids)[:n]
        out_scores = np.asarray(out_scores)[:n]
        now = time.perf_counter()
        self.batches += 1
        off = 0
        for r in batch:
            m = r.ids.shape[0]
            lat = (now - r.t0) * 1e6
            r.resolve(QueryResult(
                ids=out_ids[off:off + m, :r.k],
                scores=out_scores[off:off + m, :r.k],
                snapshot_step=index.step, latency_us=lat))
            off += m
            self.served += m
            self.latencies_us.append(lat)

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._collect_batch()
            if batch is None:
                if self._closed and self._carry is None \
                        and self._queue.empty():
                    return                 # drained: safe to exit
                continue
            try:
                self._serve_batch(batch)
            except BaseException as e:  # noqa: BLE001 — fail the batch,
                for r in batch:             # never strand its futures
                    r.fail(e)
                log.exception("batch of %d %s queries failed",
                              len(batch), batch[0].kind)
