"""Deterministic serve-side chaos: kill/restart the snapshot watcher
mid-swap and prove no query is dropped or served from a torn table.

Mirrors :mod:`repro.train.chaos`: a frozen :class:`ServeChaosSchedule`
scripts *which event fires at which query ordinal* — publish a new
checkpoint, crash the watcher, restart it — so the same schedule replays
the same interleaving. :func:`run_serve_chaos` executes it end to end
and audits every response after the fact:

* **dropped** — a request accepted by :meth:`EmbeddingServer.submit`
  whose future never resolved. The drain-on-close contract says this is
  always 0.
* **torn** — a response that does not bit-match the dense oracle
  (:func:`~repro.serve.query.dense_topk`) recomputed from the *exact
  snapshot step the response claims* (``snapshot_step``). A batch that
  read a half-swapped table would answer from no published step and
  fail this audit; one-index-per-batch makes it impossible.

The pass bar (asserted by ``tests/test_serve.py`` and gated via
``bench_serve``'s ``serve/chaos`` row): ``dropped == 0``, ``torn == 0``,
every scheduled crash fired, and the checkpoint published while the
watcher was dead is picked up after restart (hot-swap liveness).
"""
from __future__ import annotations

import dataclasses
import logging
import tempfile
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.distributed.vocab_placement import VocabPlacement
from repro.serve.query import dense_topk
from repro.serve.server import EmbeddingServer
from repro.serve.snapshot import SnapshotWatcher

log = logging.getLogger("repro.serve.chaos")


@dataclasses.dataclass(frozen=True)
class ServeChaosSchedule:
    """A deterministic serve-fault script plus its synthetic workload."""

    n_queries: int = 48
    publish_at: Tuple[int, ...] = (0, 12, 24)   # query ordinals; 0 = boot
    crash_at: Tuple[int, ...] = (20,)           # watcher dies before #24's
    restart_at: Tuple[int, ...] = (32,)         # publish, restarts after
    vocab_size: int = 96
    hot: int = 16
    dim: int = 16
    train_shards: int = 2       # checkpoints written in this stripe layout
    batch_size: int = 8
    k: int = 5
    deadline_ms: float = 1.0
    poll_s: float = 0.02
    seed: int = 0

    @property
    def n_events(self) -> int:
        return (len(self.publish_at) + len(self.crash_at)
                + len(self.restart_at))


SCHEDULES: Dict[str, ServeChaosSchedule] = {
    # The acceptance bar: one live swap, then a crash, a publish into the
    # dead window, and a restart that must pick the missed step up.
    "ci": ServeChaosSchedule(),
    "smoke": ServeChaosSchedule(n_queries=16, publish_at=(0, 6),
                                crash_at=(), restart_at=()),
    "none": ServeChaosSchedule(publish_at=(0,), crash_at=(),
                               restart_at=()),
}


def _publish(ckpt_dir: str, step: int, table: np.ndarray,
             placement: VocabPlacement) -> np.ndarray:
    """Write `table` as a real split-format checkpoint (both tables +
    placement extra, like ``TrainSession.save_checkpoint``); returns the
    normalized dense table — the oracle for responses claiming `step`."""
    from repro.train import checkpoint as ckpt

    hot, cold = placement.split(table)
    tree = {"hot_in": hot, "cold_in": cold,
            "hot_out": hot * 0.5, "cold_out": cold * 0.5}
    ckpt.save(ckpt_dir, step, tree,
              extra={"vocab_shard": placement.to_extra(),
                     "batches_seen": step})
    norm = np.maximum(np.linalg.norm(table, axis=1, keepdims=True), 1e-12)
    return (table / norm).astype(np.float32)


def _wait(pred, timeout: float, what: str) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.005)
    raise TimeoutError(f"chaos: timed out waiting for {what}")


def run_serve_chaos(schedule: ServeChaosSchedule, *,
                    ckpt_dir: Optional[str] = None,
                    mesh=None, timeout: float = 60.0) -> Dict:
    """Run `schedule` end to end; returns the audit/metrics dict.

    ``dropped`` and ``torn`` are the headline counters — both must be 0.
    """
    rng = np.random.default_rng(schedule.seed)
    placement = VocabPlacement(vocab_size=schedule.vocab_size,
                               hot=schedule.hot,
                               n_shards=schedule.train_shards)

    owns_dir = ckpt_dir is None
    tmp = tempfile.mkdtemp(prefix="serve_chaos_") if owns_dir else ckpt_dir
    oracles: Dict[int, np.ndarray] = {}     # step -> normalized (V, d)
    next_step = [0]

    def publish() -> int:
        next_step[0] += 10
        step = next_step[0]
        table = rng.standard_normal(
            (schedule.vocab_size, schedule.dim)).astype(np.float32)
        oracles[step] = _publish(tmp, step, table, placement)
        log.info("chaos: published step %d", step)
        return step

    t0 = time.perf_counter()
    crashes_fired = restarts_fired = 0
    dead_window_step = None      # step published while the watcher was dead
    pending = []                 # (request, query ids)
    try:
        if 0 in schedule.publish_at:
            publish()
        watcher = SnapshotWatcher(tmp, mesh=mesh, poll_s=schedule.poll_s)
        watcher.start()
        watcher.wait_ready(timeout=timeout)
        server = EmbeddingServer(watcher, batch_size=schedule.batch_size,
                                 deadline_ms=schedule.deadline_ms,
                                 k=schedule.k)
        for i in range(schedule.n_queries):
            if i in schedule.crash_at:
                watcher.inject_crash()
                _wait(lambda: not watcher.alive, timeout, "watcher crash")
                crashes_fired += 1
            if i in schedule.publish_at and i > 0:
                step = publish()
                if watcher.alive:
                    # live swap: wait for pickup so the swap provably
                    # lands *between* query i-1 and some later query
                    _wait(lambda: watcher.ready
                          and watcher.current().step == step,
                          timeout, f"swap to step {step}")
                else:
                    dead_window_step = step
            if i in schedule.restart_at:
                watcher.start()
                restarts_fired += 1
                if dead_window_step is not None:
                    # hot-swap liveness: the missed publish must be
                    # picked up without restarting the *server*
                    _wait(lambda: watcher.current().step
                          == dead_window_step,
                          timeout, f"post-restart swap to "
                          f"{dead_window_step}")
            n = 1 + int(rng.integers(schedule.batch_size))
            ids = rng.integers(schedule.vocab_size, size=n).astype(np.int32)
            pending.append((server.submit("nn", ids), ids))
        server.close(timeout=timeout)       # drain: answers everything
        watcher.stop()

        dropped = torn = unresolved_errors = 0
        steps_served = set()
        for req, ids in pending:
            if not req.event.is_set():
                dropped += 1
                continue
            if req.error is not None:
                unresolved_errors += 1
                continue
            res = req.result
            if res.snapshot_step not in oracles:
                torn += 1                    # answered from no real step
                continue
            steps_served.add(res.snapshot_step)
            want_ids, want_sc = dense_topk(
                oracles[res.snapshot_step], ids, k=schedule.k, mode="nn")
            if not (np.array_equal(res.ids, want_ids)
                    and np.allclose(res.scores, want_sc, atol=1e-5)):
                torn += 1
        wall = time.perf_counter() - t0
        return {
            "queries": len(pending),
            "dropped": dropped,
            "torn": torn,
            "errors": unresolved_errors,
            "swaps": watcher.swaps,
            "crashes": watcher.crashes,
            "crashes_fired": crashes_fired,
            "restarts_fired": restarts_fired,
            "load_failures": watcher.load_failures,
            "publishes": len(oracles),
            "steps_served": len(steps_served),
            "final_step_served": (watcher._index.step
                                  if watcher._index is not None else None),
            "served": server.served,
            "batches": server.batches,
            "wall_seconds": round(wall, 3),
        }
    finally:
        if owns_dir:
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)
