"""Snapshot hot-swap: a long-running server follows a training run's
checkpoint stream with zero dropped queries.

:class:`SnapshotWatcher` polls ``checkpoint.latest_step`` on a cadence,
and when a newer step appears it **stages** the new
:class:`~repro.serve.index.EmbeddingIndex` fully on device and then
atomically flips the serving pointer (one reference assignment). The
protocol (DESIGN.md §10):

* **Stage-then-flip** — the new snapshot is loaded, placed, normalized,
  and ``block_until_ready`` *before* the flip; at no point does a query
  see a half-loaded table.
* **In-flight queries finish on the old snapshot** — the server takes
  one index reference per batch (``current()``); a flip changes what the
  *next* batch sees, never a batch already scoring. The old index stays
  alive (GC'd when the last batch drops it).
* **Publisher faults are survivable** — ``latest_step`` already cleans
  interrupted publishes and quarantines partial directories (DESIGN.md
  §9); a load that still fails (e.g. the publish landed between poll and
  read) is logged, counted (``load_failures``), and retried next tick —
  the previous snapshot keeps serving.

``inject_crash()`` kills the watcher thread at its next tick (the chaos
harness's deterministic stand-in for a SIGKILL'd watcher process);
``start()`` restarts a crashed watcher, re-scanning from whatever the
newest checkpoint now is.
"""
from __future__ import annotations

import logging
import threading
import time
from typing import Callable, Optional

from repro.serve.index import EmbeddingIndex

log = logging.getLogger("repro.serve.snapshot")


class WatcherCrash(RuntimeError):
    """Injected watcher-thread crash (chaos harness only)."""


class SnapshotWatcher:
    """Follow a checkpoint directory; hot-swap the served index.

    Parameters
    ----------
    ckpt_dir : checkpoint directory a (possibly live) training run
        publishes into.
    mesh : serving mesh handed to ``EmbeddingIndex.load``.
    poll_s : poll cadence for ``checkpoint.latest_step``.
    on_swap : callback ``(old_index | None, new_index)`` after every flip.
    loader : index factory (tests substitute failure-injecting loaders).
    """

    def __init__(self, ckpt_dir: str, mesh=None, poll_s: float = 0.25,
                 on_swap: Optional[Callable] = None,
                 loader: Callable = EmbeddingIndex.load):
        self.ckpt_dir = ckpt_dir
        self.mesh = mesh
        self.poll_s = poll_s
        self.on_swap = on_swap
        self.loader = loader
        self._index: Optional[EmbeddingIndex] = None
        self._lock = threading.Lock()     # guards thread start/stop, not reads
        self._stop = threading.Event()
        self._crash = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.swaps = 0
        self.load_failures = 0
        self.crashes = 0
        self.polls = 0

    # -- serving side --------------------------------------------------------
    def current(self) -> EmbeddingIndex:
        """The serving snapshot — one atomic reference read. Callers hold
        the returned index for a whole batch, so a concurrent flip never
        tears a batch."""
        idx = self._index
        if idx is None:
            raise RuntimeError(
                f"no snapshot loaded yet from {self.ckpt_dir} "
                f"(call wait_ready or check the checkpoint dir)")
        return idx

    index = current   # alias

    @property
    def ready(self) -> bool:
        """True once a first snapshot is serving."""
        return self._index is not None

    def wait_ready(self, timeout: float = 30.0) -> EmbeddingIndex:
        """Block until the first snapshot is staged (the server's startup
        barrier)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._index is not None:
                return self._index
            if self._thread is None or not self._thread.is_alive():
                # crashed/never started: try one synchronous load so a
                # caller without a running watcher still gets an index
                self.poll_once()
                if self._index is not None:
                    return self._index
            time.sleep(0.01)
        raise TimeoutError(
            f"no usable checkpoint appeared under {self.ckpt_dir} "
            f"within {timeout:.1f}s")

    # -- watcher side --------------------------------------------------------
    def poll_once(self) -> bool:
        """One poll: stage + flip if a newer step is published. Returns
        True when a swap happened. Load failures are counted and
        swallowed — the previous snapshot keeps serving."""
        from repro.train import checkpoint as ckpt

        self.polls += 1
        try:
            step = ckpt.latest_step(self.ckpt_dir)
        except OSError as e:               # directory vanished mid-scan
            log.warning("snapshot poll failed on %s: %s", self.ckpt_dir, e)
            self.load_failures += 1
            return False
        cur = self._index
        if step is None or (cur is not None and cur.step == step):
            return False
        try:
            new = self.loader(self.ckpt_dir, step=step, mesh=self.mesh)
        except Exception as e:  # noqa: BLE001 — any load fault: keep serving
            log.warning("snapshot load of step %s failed (%s) — keeping "
                        "step %s", step, e,
                        cur.step if cur is not None else None)
            self.load_failures += 1
            return False
        self._index = new                  # the atomic flip
        self.swaps += 1
        log.info("snapshot swap: step %s -> %s (swap #%d)",
                 cur.step if cur is not None else None, new.step, self.swaps)
        if self.on_swap is not None:
            self.on_swap(cur, new)
        return True

    def _run(self) -> None:
        try:
            while not self._stop.is_set():
                if self._crash.is_set():
                    self._crash.clear()
                    raise WatcherCrash("injected watcher crash")
                self.poll_once()
                self._stop.wait(self.poll_s)
        except WatcherCrash:
            self.crashes += 1
            log.warning("snapshot watcher crashed (injected); serving "
                        "continues on step %s until restart",
                        self._index.step if self._index else None)

    def start(self) -> "SnapshotWatcher":
        """Start (or restart after a crash) the watcher thread."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="snapshot-watcher", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the watcher (the served index stays available)."""
        with self._lock:
            self._stop.set()
            if self._thread is not None:
                self._thread.join(timeout=10.0)
                self._thread = None

    @property
    def alive(self) -> bool:
        """True while the watcher thread is running."""
        return self._thread is not None and self._thread.is_alive()

    def inject_crash(self) -> None:
        """Chaos hook: the watcher thread dies at its next tick (serving
        is unaffected; ``start()`` restarts it)."""
        self._crash.set()

    def __enter__(self) -> "SnapshotWatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
