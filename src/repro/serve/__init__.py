"""Embedding query serving (DESIGN.md §10).

The serving leg of the reproduction: trained tables leave
``TrainSession`` through the PR 5 split-checkpoint format and are served
as batched nearest-neighbour / analogy top-k directly over the *sharded*
layout — per-shard partial top-k plus a cross-shard merge, never
reassembling the ``(V, d)`` table on one host. FULL-W2V's reuse
hierarchy applies unchanged: the normalized tables stay resident in
device memory and every query batch amortizes the HBM sweep over B
queries, exactly like the training kernel amortizes it over a window
tile.

Modules:

* :mod:`repro.serve.index`    — :class:`EmbeddingIndex`: checkpoint →
  per-shard pre-normalized device buffers.
* :mod:`repro.serve.query`    — jitted sharded top-k (+ the dense
  single-host jnp oracle the parity tests compare against).
* :mod:`repro.serve.snapshot` — :class:`SnapshotWatcher`: hot-swap from
  an in-progress training run's checkpoint stream.
* :mod:`repro.serve.server`   — :class:`EmbeddingServer`: deadline/
  max-batch request coalescing in front of the jitted path.
* :mod:`repro.serve.chaos`    — deterministic serve-side chaos harness
  (watcher kill/restart mid-swap; no dropped or torn queries).
"""
from repro.serve.index import EmbeddingIndex
from repro.serve.query import dense_topk, make_topk_fn
from repro.serve.server import EmbeddingServer
from repro.serve.snapshot import SnapshotWatcher

__all__ = ["EmbeddingIndex", "EmbeddingServer", "SnapshotWatcher",
           "dense_topk", "make_topk_fn"]
