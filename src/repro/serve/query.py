"""Batched nearest-neighbour / analogy top-k over sharded tables.

The sharded path runs under ``shard_map`` on the mesh ``data`` axis
(DESIGN.md §10):

1. **Query-row gather** — hot rows come from the local replica; each
   cold query row is contributed by its owner shard and ``psum``'d, so
   every shard holds the full ``(B, d)`` query block for O(B·d)
   interconnect bytes — the serving analogue of the training exchange's
   O(distinct·d).
2. **Partial top-k** — each shard scores the candidates it is
   responsible for (shard 0 additionally scores the replicated hot head,
   so no candidate is scored twice) and takes a local
   ``jax.lax.top_k``.
3. **Cross-shard merge** — the ``n·k`` per-shard partials are
   ``all_gather``'d and re-ranked by ``(score desc, id asc)``; ties
   break identically to the dense oracle, which ranks with the same
   lexicographic key.

:func:`dense_topk` is the single-host jnp oracle: the same math on the
merged ``(V, d)`` table, kept as the parity reference the tests and the
serve-smoke CI job compare against (ids identical, scores within 1e-6).

Query encodings (ids are global vocabulary ids):

* ``mode="nn"``      — ``ids (B,)``: cosine neighbours of each word;
  the word itself is excluded from its candidates.
* ``mode="analogy"`` — ``ids (B, 3)`` rows ``(a, b, c)``: neighbours of
  the normalized ``a − b + c`` offset vector (3CosAdd); a, b, c are all
  excluded.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.vocab_placement import VocabPlacement

NEG_INF = -jnp.inf


def _rank(scores: jax.Array, ids: jax.Array, k: int
          ) -> Tuple[jax.Array, jax.Array]:
    """Top-k by ``(score desc, id asc)`` — the one ranking rule both the
    sharded merge and the dense oracle use, so ties cannot diverge."""
    order = jnp.lexsort((ids, -scores), axis=-1)[..., :k]
    return (jnp.take_along_axis(ids, order, axis=-1),
            jnp.take_along_axis(scores, order, axis=-1))


def _query_vectors(hot: jax.Array, cold: jax.Array, flat_ids: jax.Array,
                   placement: VocabPlacement, axis_name: str) -> jax.Array:
    """Gather normalized rows for global ids under shard_map: hot rows
    from the local replica, cold rows psum'd from their owner shard."""
    n, hot_n = placement.n_shards, placement.hot
    is_hot = flat_ids < hot_n
    hot_part = jnp.where(
        is_hot[:, None], hot[jnp.clip(flat_ids, 0, hot_n - 1)], 0.0)
    c = flat_ids - hot_n
    mine = (~is_hot) & (c % n == jax.lax.axis_index(axis_name))
    local = jnp.clip(c // n, 0, cold.shape[0] - 1)
    cold_part = jnp.where(mine[:, None], cold[local], 0.0)
    return hot_part + jax.lax.psum(cold_part, axis_name)


def _combine(rows: jax.Array, ids: jax.Array, mode: str
             ) -> Tuple[jax.Array, jax.Array]:
    """(query vectors (B, d), excluded ids (B, E)) for a query batch."""
    if mode == "nn":
        return rows, ids[:, None]
    if mode == "analogy":
        r = rows.reshape(ids.shape[0], 3, -1)
        q = r[:, 0] - r[:, 1] + r[:, 2]
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                            1e-12)
        return q, ids
    raise ValueError(f"unknown query mode {mode!r} (nn | analogy)")


def make_topk_fn(placement: VocabPlacement, mesh, mode: str = "nn",
                 k: int = 5) -> Callable:
    """Build the jitted sharded top-k: ``fn(hot, cold, ids) -> (ids,
    scores)``, both ``(B, k)``. ``ids`` is ``(B,)`` for ``mode="nn"``,
    ``(B, 3)`` for ``mode="analogy"``; out-of-range/padded query slots
    are tolerated (clipped gathers) — callers mask their results.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, hot_n, v = placement.n_shards, placement.hot, placement.vocab_size
    cps = placement.cold_per_shard
    if k > hot_n + cps:
        raise ValueError(
            f"k={k} exceeds per-shard candidate count {hot_n + cps} "
            f"(hot={hot_n} + cold_per_shard={cps})")
    if mode not in ("nn", "analogy"):
        raise ValueError(f"unknown query mode {mode!r} (nn | analogy)")

    def local(hot, cold, ids):
        s = jax.lax.axis_index("data")
        flat = ids.reshape(-1).astype(jnp.int32)
        rows = _query_vectors(hot, cold, flat, placement, "data")
        q, excl = _combine(rows, ids.astype(jnp.int32), mode)
        # candidates this shard is responsible for: the hot head (shard 0
        # only, so replicated rows are scored exactly once) + its cold block
        cand = jnp.concatenate([hot, cold], axis=0)       # (hot_n + cps, d)
        gids = jnp.concatenate([
            jnp.arange(hot_n, dtype=jnp.int32),
            hot_n + s.astype(jnp.int32)
            + jnp.arange(cps, dtype=jnp.int32) * n])
        scores = q @ cand.T                               # (B, hot_n + cps)
        dead = (gids >= v)[None, :]                       # cold padding rows
        dead |= (jnp.arange(hot_n + cps) < hot_n)[None, :] & (s != 0)
        dead |= (gids[None, None, :] == excl[:, :, None]).any(axis=1)
        scores = jnp.where(dead, NEG_INF, scores)
        ids_l, sc_l = _rank(scores, jnp.broadcast_to(gids, scores.shape), k)
        # cross-shard merge: n·k partials, re-ranked by the same rule
        g_sc = jax.lax.all_gather(sc_l, "data")           # (n, B, k)
        g_id = jax.lax.all_gather(ids_l, "data")
        g_sc = jnp.moveaxis(g_sc, 0, 1).reshape(ids.shape[0], n * k)
        g_id = jnp.moveaxis(g_id, 0, 1).reshape(ids.shape[0], n * k)
        return _rank(g_sc, g_id, k)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P("data"), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(sharded)


def dense_topk(emb: np.ndarray, ids: np.ndarray, k: int = 5,
               mode: str = "nn", normalized: bool = True
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Single-host jnp oracle on a merged ``(V, d)`` table — the parity
    reference for the sharded path (same gather math, same exclusions,
    same ``(score desc, id asc)`` ranking). ``normalized=False``
    L2-normalizes rows first (e.g. a raw ``TrainSession.embeddings()``
    table)."""
    emb = jnp.asarray(np.asarray(emb, np.float32))
    if not normalized:
        emb = emb / jnp.maximum(
            jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-12)
    ids = jnp.asarray(np.asarray(ids, np.int32))
    rows = emb[ids.reshape(-1)]
    q, excl = _combine(rows, ids, mode)
    scores = q @ emb.T                                    # (B, V)
    gids = jnp.arange(emb.shape[0], dtype=jnp.int32)
    dead = (gids[None, None, :] == excl[:, :, None]).any(axis=1)
    scores = jnp.where(dead, NEG_INF, scores)
    out_ids, out_sc = _rank(scores, jnp.broadcast_to(gids, scores.shape), k)
    return np.asarray(out_ids), np.asarray(out_sc)
