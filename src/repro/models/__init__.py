from repro.models.lm import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
    zero_cache,
)

__all__ = [
    "abstract_params", "decode_step", "forward", "init_cache",
    "init_params", "lm_loss", "prefill", "zero_cache",
]
