"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU.

Attention is computed in query chunks (lax.scan over blocks of queries) so
32k-context prefill never materializes the full S×S score matrix — the
VMEM/HBM-friendly formulation for TPU (flash-style blocking at the XLA
level; a Pallas flash kernel is an optional further step, see EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, jax.Array]

ATTN_Q_CHUNK = 1024  # query block size for chunked causal attention


# --------------------------------------------------------------------------
# norms / rotary
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, causal, query-chunked)
# --------------------------------------------------------------------------
def init_attention(cfg: ArchConfig, key: jax.Array,
                   dtype=jnp.float32) -> Params:
    d, nh, nkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, nh, hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, nkv, hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, nkv, hd), dtype) * s,
        "wo": jax.random.normal(k4, (nh, hd, d), dtype) * (nh * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(cfg: ArchConfig, p: Params, x: jax.Array,
         positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    from repro.distributed.sharding import constrain
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped(q: jax.Array, nkv: int) -> jax.Array:
    """(B,S,nh,hd) -> (B,S,nkv,group,hd)."""
    b, s, nh, hd = q.shape
    return q.reshape(b, s, nkv, nh // nkv, hd)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     n_q_chunks: int = 16, n_kv_chunks: int = 8) -> jax.Array:
    """Flash-style causal attention, statically unrolled, head-sharded.

    Online-softmax over kv chunks inside a python loop over q chunks: the
    full S×S probability matrix is never materialized (per-body transient is
    qc×kc), causally-dead kv chunks are skipped at trace time, and — because
    there are no inner lax loops — XLA cost_analysis counts every FLOP
    (see launch/roofline.py loop-correction notes).

    GQA is flattened: k/v are repeated to the full head count so that ALL
    attention tensors shard on the head dim over the `model` axis (GSPMD
    pads uneven head counts, e.g. 24 heads / 16 devices). Matmuls run in
    bf16 with f32 accumulation; softmax state (m, l, acc) is f32.

    q: (B,Sq,nh,hd), k/v: (B,Sk,nkv,hd); self-attention (q_offset = 0).
    """
    from repro.distributed.sharding import constrain
    b, sq, nh, hd = q.shape
    sk = k.shape[1]
    nkv = k.shape[2]
    scale = hd ** -0.5
    # repeat KV to full heads; every device materializes only its head shard
    k = constrain(jnp.repeat(k, nh // nkv, axis=2),
                  "batch", "seq", "heads", None)
    v = constrain(jnp.repeat(v, nh // nkv, axis=2),
                  "batch", "seq", "heads", None)
    qc = max(1, _ceil_div(sq, n_q_chunks))
    kc = max(1, _ceil_div(sk, n_kv_chunks))

    out_chunks = []
    for qi in range(_ceil_div(sq, qc)):
        q0, q1 = qi * qc, min((qi + 1) * qc, sq)
        q_blk = q[:, q0:q1]
        qlen = q1 - q0
        m = jnp.full((b, qlen, nh), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, qlen, nh), jnp.float32)
        acc = jnp.zeros((b, qlen, nh, hd), jnp.float32)
        for ki in range(_ceil_div(min(q1, sk), kc)):
            k0, k1 = ki * kc, min((ki + 1) * kc, sk)
            k_blk = k[:, k0:k1]
            v_blk = v[:, k0:k1]
            logits = jax.lax.dot_general(
                q_blk, k_blk,
                (((3,), (3,)), ((0, 2), (0, 2))),
                preferred_element_type=jnp.float32)          # (B,nh,qc,kc)
            logits = jnp.moveaxis(logits, 1, 2) * scale      # (B,qc,nh,kc)
            if k1 > q0:                          # chunk touches the diagonal
                qpos = q0 + jnp.arange(qlen)
                kpos = k0 + jnp.arange(k1 - k0)
                mask = kpos[None, :] <= qpos[:, None]
                logits = jnp.where(mask[:, None, :][None], logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(-1))
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(logits - safe_m[..., None])
            p = jnp.where(jnp.isfinite(logits), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l = l * alpha + p.sum(-1)
            pv = jax.lax.dot_general(
                p.astype(v_blk.dtype), v_blk,
                (((3,), (1,)), ((0, 2), (0, 2))),
                preferred_element_type=jnp.float32)          # (B,nh,qc,hd)
            acc = acc * alpha[..., None] + jnp.moveaxis(pv, 1, 2)
            m = m_new
        out_chunks.append(acc / jnp.maximum(l[..., None], 1e-30))
    out = jnp.concatenate(out_chunks, axis=1)
    return constrain(out.astype(q.dtype), "batch", "seq", "heads", None)


def attention_block(cfg: ArchConfig, p: Params, x: jax.Array,
                    positions: jax.Array, return_kv: bool = False):
    q, k, v = _qkv(cfg, p, x, positions)
    o = causal_attention(q, k, v)
    b, s, nh, hd = o.shape
    out = rp_dot(o.reshape(b, s, nh * hd),
                 p["wo"].reshape(nh * hd, -1), cfg.bf16_reduce)
    if return_kv:
        return out, k, v
    return out


def attention_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode. x: (B,1,d); caches: (B,S,nkv,hd)."""
    b, _, d = x.shape
    s_max = k_cache.shape[1]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q, k, v = _qkv(cfg, p, x, pos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), cache_len, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), cache_len, 1)
    nkv = k_cache.shape[2]
    qg = _grouped(q, nkv)                                     # (B,1,nkv,g,hd)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bqkgs", qg, k_cache) * scale
    valid = jnp.arange(s_max) <= cache_len                    # (S,)
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(v_cache.dtype)
    o = jnp.einsum("bqkgs,bskh->bqkgh", w, v_cache)
    o = o.reshape(b, 1, cfg.n_heads, q.shape[-1])
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k_cache, v_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def init_mlp(d: int, ff: int, key: jax.Array, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, ff), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (ff, d), dtype) * ff ** -0.5,
    }


def rp_dot(a: jax.Array, b: jax.Array, bf16_out: bool) -> jax.Array:
    """Row-parallel projection (contraction dim TP-sharded -> psum after).
    bf16_out makes the partial sums (and hence the TP all-reduce) bf16."""
    out = jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.bfloat16 if bf16_out else None)
    return out


def mlp_block(p: Params, x: jax.Array, bf16_reduce: bool = False) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return rp_dot(h, p["w_down"], bf16_reduce)
