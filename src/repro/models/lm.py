"""Decoder LM assembly: scan-over-blocks forward, prefill, and decode.

Layers are stacked along a leading ``n_blocks`` dim and consumed by
``lax.scan`` (compile time O(1) in depth — essential for 95-layer configs on
the 512-device dry-run). Hybrid archs (Jamba) scan over repeating
``len(pattern)``-layer blocks with per-position parameter stacks.

``[audio]``/``[vlm]`` archs prepend precomputed ``prefix_embeds`` (the
modality-frontend stub per the assignment) to the token embeddings.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    attention_block,
    attention_decode,
    init_attention,
    init_mlp,
    mlp_block,
    rms_norm,
)

Params = Dict


def block_pattern(cfg: ArchConfig) -> Tuple[str, ...]:
    kinds = cfg.layer_kinds()
    pat = cfg.hybrid_pattern or (kinds[0],)
    return tuple(pat)


def n_blocks(cfg: ArchConfig) -> int:
    return cfg.n_layers // len(block_pattern(cfg))


def _uses_moe(cfg: ArchConfig, pos: int) -> bool:
    return cfg.moe is not None and cfg.d_ff > 0 and pos % cfg.moe_every == 0


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_layer(cfg: ArchConfig, kind: str, pos: int, key: jax.Array,
                dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {"pre_norm": jnp.ones((d,), dtype)}
    if kind == "attn":
        p["mixer"] = init_attention(cfg, k1, dtype)
    else:
        p["mixer"] = ssm_mod.init_mamba(cfg, k1, dtype)
    if cfg.d_ff > 0:
        p["post_norm"] = jnp.ones((d,), dtype)
        if _uses_moe(cfg, pos):
            p["ffn"] = moe_mod.init_moe(cfg, k2, dtype)
        else:
            p["ffn"] = init_mlp(d, cfg.d_ff, k2, dtype)
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    pat = block_pattern(cfg)
    nb = n_blocks(cfg)
    keys = jax.random.split(key, len(pat) + 2)
    blocks = []
    for pos, kind in enumerate(pat):
        layer_keys = jax.random.split(keys[pos], nb)
        stacked = jax.vmap(
            lambda k, _kind=kind, _pos=pos: _init_layer(cfg, _kind, _pos, k,
                                                        dtype)
        )(layer_keys)
        blocks.append(stacked)
    params: Params = {
        "embed": jax.random.normal(keys[-2], (cfg.vocab, cfg.d_model),
                                   dtype) * cfg.d_model ** -0.5,
        "blocks": tuple(blocks),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab), dtype) * cfg.d_model ** -0.5
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree — dry-run params without allocation."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))



def _scan_blocks(cfg: ArchConfig, body, carry, blocks_xs):
    """lax.scan over layer blocks, or a python-unrolled equivalent when
    cfg.scan_layers is False (exact XLA cost_analysis — see
    launch/roofline.py). body: (carry, xs_slice) -> (carry, ys_slice)."""
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, blocks_xs)
    nb = jax.tree.leaves(blocks_xs)[0].shape[0]
    ys = []
    for i in range(nb):
        xs = jax.tree.map(lambda x: x[i], blocks_xs)
        carry, y = body(carry, xs)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


# --------------------------------------------------------------------------
# forward (train / scoring)
# --------------------------------------------------------------------------
def _apply_layer(cfg: ArchConfig, kind: str, pos: int, p: Params,
                 h: jax.Array, positions: jax.Array) -> jax.Array:
    x = rms_norm(h, p["pre_norm"], cfg.norm_eps)
    if kind == "attn":
        mix = attention_block(cfg, p["mixer"], x, positions)
    else:
        mix = ssm_mod.mamba_block(cfg, p["mixer"], x)
    h = h + mix
    if cfg.d_ff > 0:
        x = rms_norm(h, p["post_norm"], cfg.norm_eps)
        if _uses_moe(cfg, pos):
            h = h + moe_mod.moe_block(cfg, p["ffn"], x)
        else:
            h = h + mlp_block(p["ffn"], x, cfg.bf16_reduce)
    return constrain(h, "batch", "seq", "embed")


def embed_lookup(cfg: ArchConfig, embed: jax.Array,
                 tokens: jax.Array) -> jax.Array:
    """Embedding lookup against a REPLICATED table.

    A vocab-sharded table makes jnp.take's backward a scatter-add that
    GSPMD rewrites into UNSHARDED full-vocab (B,S,V) f32 one-hot
    contractions (measured: 38GB/step of gathers — EXPERIMENTS.md §Perf),
    and an explicit sharded one-hot einsum costs T·V·d FLOPs (~1000× a
    gather). So the input table is replicated (ZeRO: its optimizer state
    stays sharded — see `param_shardings(role="opt")`), the gather is
    local, and the gradient is a single all-reduce per step.
    """
    return constrain(jnp.take(embed, tokens, axis=0),
                     "batch", "seq", "embed")


def forward(cfg: ArchConfig, params: Params, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B, S) -> logits (B, S_total, V)."""
    pat = block_pattern(cfg)
    h = embed_lookup(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = constrain(h, "batch", "seq", "embed")
    b, s_total, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s_total)[None], (b, s_total))

    def body(carry, xs):
        hh = carry
        for pos, kind in enumerate(pat):
            hh = _apply_layer(cfg, kind, pos, xs[pos], hh, positions)
        return hh, None

    h, _ = _scan_blocks(cfg, body, h, params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = h @ unembed
    return constrain(logits, "batch", "seq", "vocab")


def lm_loss(cfg: ArchConfig, params: Params, tokens: jax.Array,
            labels: jax.Array,
            prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross entropy over the token region (prefix excluded).

    Shard-safe: the vocab dim stays sharded throughout — the max/sum
    reductions become small cross-`model` collectives and the gold logit is
    extracted with a fused select+reduce instead of take_along_axis (which
    would force an all-gather of the full logits)."""
    logits = forward(cfg, params, tokens, prefix_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    shifted = constrain(shifted, "batch", "seq", "vocab")
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jnp.arange(cfg.vocab)[None, None, :]
    gold_shifted = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], shifted, 0.0), axis=-1)
    return jnp.mean(logz - gold_shifted)


# --------------------------------------------------------------------------
# KV / state caches, prefill, decode
# --------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Abstract-shape factory; also usable to allocate zeros via tree_map."""
    pat = block_pattern(cfg)
    nb = n_blocks(cfg)
    hd = cfg.resolved_head_dim()
    s = cfg.ssm
    cache = []
    for kind in pat:
        if kind == "attn":
            kv = jax.ShapeDtypeStruct(
                (nb, batch, max_len, cfg.n_kv_heads, hd), dtype)
            cache.append({"k": kv, "v": kv})
        else:
            conv_ch = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
            cache.append({
                "conv": jax.ShapeDtypeStruct(
                    (nb, batch, s.d_conv - 1, conv_ch), dtype),
                "ssm": jax.ShapeDtypeStruct(
                    (nb, batch, s.n_heads(cfg.d_model), s.head_dim,
                     s.d_state), jnp.float32),
            })
    return tuple(cache)


def zero_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        init_cache(cfg, batch, max_len, dtype))


def cache_shardings(cfg: ArchConfig, rules, batch: int, max_len: int):
    """NamedShardings for the decode cache.

    Attention KV: batch over the data axes; the sequence dim additionally
    shards over `model` when the KV heads can't (GQA kv < 16 — most archs),
    and over `data` when the batch itself is unshardable (long-context
    batch=1 → sequence parallelism)."""

    def leaf(sd):
        if sd.ndim == 5 and sd.shape[2] == max_len:   # (nb,B,S,kv,hd) KV
            nb_, b, s_len, kv, hd = sd.shape
            batch_ok = b % rules._axes_size(
                rules._present(("pod", "data"))) == 0
            kv_ok = kv % rules._axes_size(rules._present("model")) == 0
            if batch_ok and kv_ok:
                axes = ("stack", "batch", None, "kv_heads", None)
            elif batch_ok:
                axes = ("stack", "batch", "kv_seq_model", "kv_heads", None)
            else:
                axes = ("stack", None, "kv_seq", "kv_heads", None)
            return rules.sharding(axes, sd.shape)
        if sd.ndim == 4:        # (nb, B, W, conv_ch) conv cache
            return rules.sharding(("stack", "batch", None, "inner"),
                                  sd.shape)
        return rules.sharding(("stack", "batch", "ssm_heads", None, None),
                              sd.shape)

    return jax.tree.map(leaf, init_cache(cfg, batch, max_len))


def prefill(cfg: ArchConfig, params: Params, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            cache_dtype=jnp.bfloat16):
    """Full-context forward that also builds the decode cache.

    Returns (last-token logits (B, V), cache, cache_len).
    """
    pat = block_pattern(cfg)
    h = embed_lookup(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = constrain(h, "batch", "seq", "embed")
    b, s_total, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s_total)[None], (b, s_total))

    def body(carry, xs):
        hh = carry
        out_cache = []
        for pos, kind in enumerate(pat):
            p = xs[pos]
            x = rms_norm(hh, p["pre_norm"], cfg.norm_eps)
            if kind == "attn":
                mix, k, v = attention_block(cfg, p["mixer"], x, positions,
                                            return_kv=True)
                out_cache.append({"k": k.astype(cache_dtype),
                                  "v": v.astype(cache_dtype)})
            else:
                mix, (conv_tail, state) = ssm_mod.mamba_block(
                    cfg, p["mixer"], x, return_cache=True)
                out_cache.append({"conv": conv_tail.astype(cache_dtype),
                                  "ssm": state})
            hh = hh + mix
            if cfg.d_ff > 0:
                x = rms_norm(hh, p["post_norm"], cfg.norm_eps)
                if _uses_moe(cfg, pos):
                    hh = hh + moe_mod.moe_block(cfg, p["ffn"], x)
                else:
                    hh = hh + mlp_block(p["ffn"], x, cfg.bf16_reduce)
            hh = constrain(hh, "batch", "seq", "embed")
        return hh, tuple(out_cache)

    h, cache = _scan_blocks(cfg, body, h, params["blocks"])
    h = rms_norm(h[:, -1], params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = h @ unembed
    return logits, cache, jnp.int32(s_total)


def decode_step(cfg: ArchConfig, params: Params, cache, cache_len: jax.Array,
                tokens: jax.Array):
    """One-token decode. tokens (B, 1) -> (logits (B, V), new cache)."""
    pat = block_pattern(cfg)
    h = embed_lookup(cfg, params["embed"], tokens)      # (B, 1, d)
    h = constrain(h, "batch", "seq", "embed")

    def body(carry, xs):
        hh = carry
        bp, cb = xs
        new_cb = []
        for pos, kind in enumerate(pat):
            p = bp[pos]
            c = cb[pos]
            x = rms_norm(hh, p["pre_norm"], cfg.norm_eps)
            if kind == "attn":
                mix, k_c, v_c = attention_decode(cfg, p["mixer"], x,
                                                 c["k"], c["v"], cache_len)
                new_cb.append({"k": k_c, "v": v_c})
            else:
                mix, conv_c, ssm_c = ssm_mod.mamba_decode(
                    cfg, p["mixer"], x, c["conv"], c["ssm"])
                new_cb.append({"conv": conv_c, "ssm": ssm_c})
            hh = hh + mix
            if cfg.d_ff > 0:
                x = rms_norm(hh, p["post_norm"], cfg.norm_eps)
                if _uses_moe(cfg, pos):
                    hh = hh + moe_mod.moe_block(cfg, p["ffn"], x)
                else:
                    hh = hh + mlp_block(p["ffn"], x, cfg.bf16_reduce)
        return hh, tuple(new_cb)

    if cfg.scan_layers:
        h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    else:
        nb_ = n_blocks(cfg)
        outs = []
        for i in range(nb_):
            xs = jax.tree.map(lambda x: x[i], (params["blocks"], cache))
            h, y = body(h, xs)
            outs.append(y)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
    h = rms_norm(h[:, 0], params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = h @ unembed
    return logits, new_cache
