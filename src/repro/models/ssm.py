"""Mamba2 (SSD — state-space duality) mixer, chunked dual form + decode step.

Train/prefill uses the SSD block decomposition (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the quadratic "attention-like"
dual form runs on the MXU, between chunks a small recurrent state (H, hd, S)
is carried by a scan. Decode is the O(1) recurrent update.

The canonical packed in_proj/conv are split into per-stream parameters
(z, x, B, C, dt — mathematically identical for depthwise conv) so each
piece shards cleanly over the mesh without halo collectives.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import rms_norm

Params = Dict[str, jax.Array]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def init_mamba(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * sc,
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * sc,
        "w_bc": jax.random.normal(ks[2], (d, 2 * gs), dtype) * sc,
        "w_dt": jax.random.normal(ks[3], (d, nh), dtype) * sc,
        "conv_x": jax.random.normal(ks[4], (s.d_conv, di), dtype) * 0.1,
        "conv_bc": jax.random.normal(ks[5], (s.d_conv, 2 * gs), dtype) * 0.1,
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_b": jnp.zeros((2 * gs,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": jax.random.normal(ks[6], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, L, C) with kernel (W, C)."""
    wlen = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(wlen):
        out = out + pad[:, j:j + x.shape[1], :] * w[j]
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array,
                bmat: jax.Array, cmat: jax.Array, chunk: int,
                state0: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD over (B, L, H, P) with chunk-wise dual form.

    x (B,L,H,P); dt (B,L,H) post-softplus; a (H,) negative;
    bmat/cmat (B,L,G,S) with G groups broadcast over H.
    Returns (y (B,L,H,P), final state (B,H,P,S)).
    """
    bsz, l, h, p = x.shape
    g, s = bmat.shape[2], bmat.shape[3]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    n = l // chunk

    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, s), jnp.float32)

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def chunk_step(state, inp):
        # python-unrolled (no lax.scan): keeps XLA cost_analysis exact and
        # lets the chunk count stay small via the adaptive chunk size
        xk, dtk, bk, ck = inp                 # (B,c,H,P),(B,c,H),(B,c,G,S)
        dta = dtk * a                          # (B,c,H)
        cum = jnp.cumsum(dta, axis=1)          # (B,c,H)
        bh = jnp.repeat(bk, rep, axis=2)       # (B,c,H,S)
        ch = jnp.repeat(ck, rep, axis=2)       # (B,c,H,S)

        # ---- intra-chunk (dual quadratic form) ----
        scores = jnp.einsum("bihs,bjhs->bhij", ch.astype(jnp.float32),
                            bh.astype(jnp.float32))           # (B,H,c,c)
        cum_t = cum.transpose(0, 2, 1)                        # (B,H,c)
        decay = jnp.exp(cum_t[:, :, :, None] - cum_t[:, :, None, :])
        m = jnp.where(causal[None, None], decay, 0.0)
        w = scores * m * dtk.transpose(0, 2, 1)[:, :, None, :]  # × dt_j
        y_intra = jnp.einsum("bhij,bjhp->bihp", w, xk.astype(jnp.float32))

        # ---- inter-chunk ----
        seg = jnp.exp(cum[:, -1:, :] - cum)                   # (B,c,H)
        contrib = jnp.einsum("bjh,bjhs,bjhp->bhps",
                             (seg * dtk).astype(jnp.float32),
                             bh.astype(jnp.float32),
                             xk.astype(jnp.float32))          # (B,H,P,S)
        y_inter = jnp.einsum("bihs,bhps,bih->bihp",
                             ch.astype(jnp.float32), state,
                             jnp.exp(cum))
        new_state = state * jnp.exp(cum[:, -1])[..., None, None] + contrib
        return new_state, (y_intra + y_inter).astype(x.dtype)

    state = state0
    ys = []
    for ci in range(n):
        sl = slice(ci * chunk, (ci + 1) * chunk)
        state, yk = chunk_step(state, (x[:, sl], dt[:, sl],
                                       bmat[:, sl], cmat[:, sl]))
        ys.append(yk)
    y = jnp.concatenate(ys, axis=1)
    return y, state


def _project(cfg: ArchConfig, p: Params, x: jax.Array):
    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    bcx = x @ p["w_bc"]
    dt = x @ p["w_dt"]
    return z, xin, bcx, dt


def mamba_block(cfg: ArchConfig, p: Params, x: jax.Array,
                state0=None, return_state: bool = False,
                return_cache: bool = False):
    """Full Mamba2 mixer. x: (B, L, d)."""
    s = cfg.ssm
    bsz, l, d = x.shape
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state

    z, xin, bcx, dt = _project(cfg, p, x)
    if return_cache:
        # raw (pre-conv) stream tail feeds the decode conv window
        conv_tail = jnp.concatenate([xin, bcx], axis=-1)[:, -(s.d_conv - 1):]
    xin = _causal_conv(xin, p["conv_x"], p["conv_x_b"])
    bcx = _causal_conv(bcx, p["conv_bc"], p["conv_bc_b"])
    xh = xin.reshape(bsz, l, nh, s.head_dim)
    bmat = bcx[..., :gs].reshape(bsz, l, s.n_groups, s.d_state)
    cmat = bcx[..., gs:].reshape(bsz, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    # adaptive chunk: at most 32 chunks (python-unrolled), at least s.chunk
    chunk = min(max(s.chunk, _ceil_div(l, 32)), l)
    pad = (-l) % chunk
    if pad:
        # zero-pad to a chunk multiple; dt=0 on padding makes it a no-op for
        # the carried state (decay 1, contribution 0)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(xh, dt, a, bmat, cmat, chunk, state0)
    if pad:
        y = y[:, :l]
        xh = xh[:, :l]
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(bsz, l, s.d_inner(d))
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    if return_cache:
        return out, (conv_tail, state)
    if return_state:
        return out, state
    return out


def mamba_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                 conv_state: jax.Array, ssm_state: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent step.

    x: (B,1,d); conv_state: (B, d_conv-1, di + 2*G*S); ssm_state: (B,H,P,S).
    """
    s = cfg.ssm
    bsz, _, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state

    z, xin, bcx, dt = _project(cfg, p, x)                     # (B,1,·)
    stream = jnp.concatenate([xin, bcx], axis=-1)[:, 0]       # (B, di+2gs)
    window = jnp.concatenate([conv_state, stream[:, None]], axis=1)
    conv_state = window[:, 1:]
    wcat = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=1)
    bcat = jnp.concatenate([p["conv_x_b"], p["conv_bc_b"]], axis=0)
    conv = jax.nn.silu((window * wcat[None]).sum(1) + bcat)   # (B, di+2gs)
    xh = conv[:, :di].reshape(bsz, nh, s.head_dim)
    bvec = jnp.repeat(conv[:, di:di + gs].reshape(bsz, s.n_groups, s.d_state),
                      nh // s.n_groups, axis=1)               # (B,H,S)
    cvec = jnp.repeat(conv[:, di + gs:].reshape(bsz, s.n_groups, s.d_state),
                      nh // s.n_groups, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                   # (B,H)
    ssm_state = (ssm_state * decay[..., None, None]
                 + jnp.einsum("bh,bhs,bhp->bhps", dt,
                              bvec.astype(jnp.float32),
                              xh.astype(jnp.float32)))
    y = jnp.einsum("bhs,bhps->bhp", cvec.astype(jnp.float32), ssm_state)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"], conv_state, ssm_state
