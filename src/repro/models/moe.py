"""Mixture-of-Experts FFN with capacity-factor dense dispatch.

TPU-native formulation (no torch-style all_to_all): top-k routing, position-
in-expert via cumsum, scatter into a per-expert (E, C, d) buffer, grouped
expert GEMMs, gather+combine. Experts shard over the ``model`` mesh axis
(expert parallelism); the capacity dim shards over ``data``. Token-overflow
beyond capacity is dropped (standard Switch/GShard semantics).

Arctic-style ``dense_residual`` adds a small always-on MLP in parallel.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import init_mlp, mlp_block

Params = Dict[str, jax.Array]


def init_moe(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    moe = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, moe.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "w_router": jax.random.normal(k1, (d, e), jnp.float32) * d ** -0.5,
        "we_gate": jax.random.normal(k2, (e, d, ff), dtype) * d ** -0.5,
        "we_up": jax.random.normal(k3, (e, d, ff), dtype) * d ** -0.5,
        "we_down": jax.random.normal(k4, (e, ff, d), dtype) * ff ** -0.5,
    }
    if moe.dense_residual:
        p["residual"] = init_mlp(d, moe.dense_residual_ff, k5, dtype)
    return p


def _local_expert_pass(cfg: ArchConfig, x: jax.Array, router: jax.Array,
                       we_gate: jax.Array, we_up: jax.Array,
                       we_down: jax.Array, e0: jax.Array,
                       n_experts: int) -> jax.Array:
    """Single-device expert pass: route ALL local tokens, process the
    experts owned by this shard ([e0, e0+e_loc)), return this shard's
    partial output (T, d). Pure local ops — no collectives."""
    moe = cfg.moe
    k = moe.top_k
    t, d = x.shape
    e_loc = we_gate.shape[0]

    logits = x.astype(jnp.float32) @ router                    # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gvals, gidx = jax.lax.top_k(gates, k)                      # (T, K)
    gvals = gvals / jnp.maximum(gvals.sum(-1, keepdims=True), 1e-9)

    rel = gidx - e0                                            # (T, K)
    mine = (rel >= 0) & (rel < e_loc)
    rel_flat = jnp.where(mine, rel, e_loc).reshape(t * k)      # overflow row
    onehot = jax.nn.one_hot(rel_flat, e_loc + 1, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1    # (T*K,)
    cap = min(max(1, int(k * t * moe.capacity_factor / n_experts)), t)
    keep = mine.reshape(t * k) & (pos < cap)
    safe_pos = jnp.where(keep, pos, cap)

    xrep = jnp.repeat(x, k, axis=0)                            # (T*K, d)
    buf = jnp.zeros((e_loc + 1, cap + 1, d), x.dtype)
    buf = buf.at[rel_flat, safe_pos].add(xrep)
    buf = buf[:e_loc, :cap]                                    # (E_loc, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, we_up)
    h = jnp.einsum("ecf,efd->ecd", h, we_down)                 # (E_loc, C, d)

    hpad = jnp.pad(h, ((0, 1), (0, 1), (0, 0)))
    out = hpad[jnp.minimum(rel_flat, e_loc), safe_pos]         # (T*K, d)
    out = out * (gvals.reshape(t * k, 1).astype(out.dtype)
                 * keep[:, None].astype(out.dtype))
    return out.reshape(t, k, d).sum(1)                         # (T, d) partial


def _moe_shardmap(cfg: ArchConfig, p: Params, x: jax.Array,
                  rules) -> jax.Array:
    """Expert parallelism via shard_map: every (data, model) shard routes
    its model-replicated token block against ALL experts but processes only
    its local experts; partial outputs psum over `model` (one bf16
    stream-sized all-reduce per layer — the EP combine). Expert weights are
    ZeRO-3 sharded over the data axes and all-gathered per layer.

    Pure-pjit formulations of the dispatch scatter degenerate under GSPMD
    (multi-index scatter onto a sharded expert dim -> replication storms,
    EXPERIMENTS.md §Perf iteration 3); local scatter under shard_map is the
    production formulation (cf. MaxText/praxis).
    """
    from jax.experimental.shard_map import shard_map

    mesh = rules.mesh
    moe = cfg.moe
    b, s, d = x.shape
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # specs must MATCH the actual param shardings (incl. serve-mode fsdp
    # overrides), else pjit inserts reshards at the shard_map boundary
    fsdp_gate = rules.resolve("fsdp", p["we_gate"].shape[1],
                              allow_uneven=False)
    fsdp_down = rules.resolve("fsdp", p["we_down"].shape[2],
                              allow_uneven=False)
    gate_spec = P("model", fsdp_gate, None)
    down_spec = P("model", None, fsdp_down)
    batch_axes = (data_axes
                  if b % max(rules._axes_size(data_axes), 1) == 0 else None)

    def local_fn(xb, router, wg, wu, wd):
        # xb: (B_loc, S, d); weights: local expert blocks (ZeRO-sharded)
        if fsdp_gate is not None:
            wg = jax.lax.all_gather(wg, fsdp_gate, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_gate, axis=1, tiled=True)
        if fsdp_down is not None:
            wd = jax.lax.all_gather(wd, fsdp_down, axis=2, tiled=True)
        e_loc = wg.shape[0]
        e0 = jax.lax.axis_index("model") * e_loc
        t_loc = xb.shape[0] * xb.shape[1]
        out = _local_expert_pass(cfg, xb.reshape(t_loc, d), router,
                                 wg, wu, wd, e0, moe.num_experts)
        out = jax.lax.psum(out.astype(jnp.bfloat16), "model")
        return out.reshape(xb.shape).astype(xb.dtype)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(), gate_spec, gate_spec,
                  down_spec),
        out_specs=P(batch_axes, None, None),
        check_rep=False)
    out = fn(x, p["w_router"], p["we_gate"], p["we_up"], p["we_down"])
    if moe.dense_residual:
        out = out + mlp_block(p["residual"], x.reshape(b * s, d),
                              cfg.bf16_reduce).reshape(b, s, d)
    return out


def _dispatch_groups(t: int) -> int:
    """Number of independent dispatch groups = data-shard count (GShard's
    G dim): position-in-expert and scatter/gather stay shard-local, so the
    only cross-device MoE traffic is the expert GEMM itself."""
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    if rules is None:
        return 1
    g = rules._axes_size(rules._present(("pod", "data")))
    return g if g > 1 and t % g == 0 else 1


def moe_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    from repro.distributed.sharding import constrain, current_rules
    rules = current_rules()
    if (rules is not None and rules.mesh.shape.get("model", 1) > 1
            and cfg.moe.num_experts % rules.mesh.shape["model"] == 0
            # pure-DP rules disable expert parallelism -> local path
            and rules.resolve("experts", cfg.moe.num_experts,
                              allow_uneven=False) is not None):
        return _moe_shardmap(cfg, p, x, rules)
    moe = cfg.moe
    e, k = moe.num_experts, moe.top_k
    b, s, d = x.shape
    t = b * s
    grp = _dispatch_groups(t)
    tg = t // grp                                              # tokens/group
    xf = x.reshape(grp, tg, d)
    xf = constrain(xf, "expert_groups", None, None)

    # --- route ---
    logits = (xf.astype(jnp.float32) @ p["w_router"])          # (G, Tg, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gvals, gidx = jax.lax.top_k(gates, k)                      # (G, Tg, K)
    gvals = gvals / jnp.maximum(gvals.sum(-1, keepdims=True), 1e-9)

    # --- position-in-expert: group-local cumsum (no cross-shard prefix) ---
    flat_e = gidx.reshape(grp, tg * k)                         # (G, Tg*K)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (G, Tg*K, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1    # (G, Tg*K)
    cap = min(max(1, int(k * tg * moe.capacity_factor / e)), tg)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)                       # overflow slot

    # --- dispatch: (G, E, C+1, d) buffer. The scatter output stays
    # model-REPLICATED (each model shard redundantly builds its data
    # group's buffer — scatter onto a model-sharded expert dim would make
    # GSPMD replicate the whole dispatch with giant gathers); the GEMM
    # input is then a local slice of it.
    xrep = jnp.repeat(xf, k, axis=1)                           # (G, Tg*K, d)
    gi = jnp.arange(grp)[:, None] * jnp.ones((1, tg * k), jnp.int32)
    buf = jnp.zeros((grp, e, cap + 1, d), x.dtype)
    buf = buf.at[gi, flat_e, safe_pos].add(xrep)
    buf = constrain(buf, "expert_groups", None, None, None)
    buf = buf[:, :, :cap]                                      # (G, E, C, d)
    buf = constrain(buf, "expert_groups", "experts", None, None)  # local slice

    # --- expert GEMMs (experts over `model`, groups over `data`) ---
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["we_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["we_up"])
    h = jnp.einsum("gecf,efd->gecd", h, p["we_down"])          # (G, E, C, d)
    # combine gathers over the expert dim -> bring results model-replicated
    # (one (E,C,d)-sized all-gather per layer: the EP "combine" collective)
    h = constrain(h, "expert_groups", None, None, None)

    # --- combine (group-local gather) ---
    hpad = jnp.concatenate([h, jnp.zeros((grp, e, 1, d), h.dtype)], axis=2)
    out = hpad[gi, flat_e, safe_pos]                           # (G, Tg*K, d)
    out = out * (gvals.reshape(grp, tg * k, 1).astype(out.dtype)
                 * keep[..., None].astype(out.dtype))
    out = out.reshape(grp, tg, k, d).sum(2)                    # (G, Tg, d)

    if moe.dense_residual:
        out = out + mlp_block(p["residual"], xf, cfg.bf16_reduce)
    return out.reshape(b, s, d)
