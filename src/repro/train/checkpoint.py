"""Fault-tolerant checkpointing: atomic, sharded-aware, reshard-on-load.

Layout (one directory per step):
    <dir>/step_000100.tmp.<unique>/   (written + fsynced first)
    <dir>/step_000100/                (atomic rename when complete)
        manifest.json           (tree structure, shapes, dtypes, checksums)
        arrays.npz              (flattened leaves)

Publication is crash-atomic (DESIGN.md §9): files and the tmp directory are
fsynced before the rename, a same-step re-save displaces the old directory
by *rename* (never rmtree-then-rename, which loses the newest checkpoint if
the process dies between the two), and the parent directory is fsynced
after publish. :func:`_clean_stale` — run at every save and consulted by
:func:`latest_step` — deletes interrupted ``*.tmp.*`` writes and recovers a
displaced ``*.old.*`` directory whose final name went missing mid-publish,
but only once such a directory is :data:`STALE_GRACE_S` old — younger ones
may belong to a publisher that is still mid-rename. That grace is what lets
a *concurrent reader* (the serving snapshot watcher polling
``latest_step`` while a supervisor trains and publishes into the same
directory) share the directory safely without any cross-process locking.

Reads are defensive: a directory that cannot be read back (truncated
``arrays.npz``, unparseable manifest, checksum mismatch) raises
:class:`CorruptCheckpoint`; :func:`restore` with ``step=None`` and
:func:`latest_step` *quarantine* such a directory (rename to
``step_N.corrupt*``) and fall back to the previous step instead of killing
the run. Structural mismatches (wrong shapes, missing leaves) still raise —
those are caller errors, not disk faults.

Restore works onto ANY mesh/sharding (elastic restarts): arrays are loaded
host-side and re-placed with `jax.device_put` against the target shardings —
the resharding path a 1000-node deployment needs when the surviving device
set changes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import re
import shutil
import tempfile
import time
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")


class CorruptCheckpoint(IOError):
    """A checkpoint directory that cannot be read back: truncated or
    missing ``arrays.npz``, unparseable ``manifest.json``, or a checksum
    mismatch. Latest-step restores quarantine the directory and fall back
    to the previous step; explicit-step restores quarantine and re-raise."""


@dataclasses.dataclass
class PipelineCursor:
    """Host-pipeline position stored with every W2V checkpoint.

    Because batching randomness is keyed by ``(seed, epoch, batch_index)``
    (DESIGN.md §4.1), this pair is the *complete* input-pipeline state: on
    resume the pipeline fast-forwards with ``skip_batches=epoch_batch`` and
    reproduces the exact remainder of the interrupted epoch — for any
    ``prefetch_workers`` count, including one different from the run that
    wrote the checkpoint. ``prefetch_workers`` is recorded for provenance
    only, never replayed.
    """
    epoch: int = 0
    epoch_batch: int = 0        # batches already trained in `epoch`
    prefetch_workers: int = 0   # worker count of the writing run (info only)

    def to_extra(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "epoch_batch": self.epoch_batch,
                "prefetch_workers": self.prefetch_workers}

    @classmethod
    def from_extra(cls, extra: Dict[str, Any]) -> "PipelineCursor":
        return cls(epoch=int(extra.get("epoch", 0)),
                   epoch_batch=int(extra.get("epoch_batch", 0)),
                   prefetch_workers=int(extra.get("prefetch_workers", 0)))


def np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype string, including extended dtypes
    (``bfloat16``) that plain numpy only knows once ``ml_dtypes`` is
    registered (importing jax does that; this fallback covers tools that
    read manifests without it)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            elif hasattr(k, "name"):
                keys.append(str(k.name))
            else:
                keys.append(str(k))
        out.append(("/".join(keys), leaf))
    return out


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory entries need their own
    fsync for the rename to be durable)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# How old a step_N.tmp.* / step_N.old.* directory must be before
# maintenance touches it. A live publisher's in-flight dirs are always
# younger than this (a publish is seconds at most); anything older is a
# crash leftover. Concurrent readers (the serving snapshot watcher
# polling latest_step while a TrainSupervisor publishes) rely on this:
# without the grace, a reader would rm -rf the publisher's tmp dir out
# from under its rename, or rename a displaced .old back into a final
# the publisher is about to rename onto.
STALE_GRACE_S = 60.0


def _older_than(path: str, grace_s: float) -> bool:
    if grace_s <= 0:
        return True
    try:
        return (time.time() - os.path.getmtime(path)) >= grace_s
    except OSError:        # vanished under a concurrent cleaner
        return False


def _clean_stale(ckpt_dir: str, grace_s: float = STALE_GRACE_S) -> None:
    """Remove interrupted publishes; recover displaced finals.

    ``step_N.tmp*`` directories are incomplete writes — deleted. A
    ``step_N.old.*`` directory is a *complete* checkpoint displaced by a
    re-save of the same step: if the crash hit the window between the two
    renames (so ``step_N`` itself is missing), rename it back — the
    checkpoint is not lost; otherwise delete it.

    Both actions are gated on the directory being at least ``grace_s``
    old: fresh tmp/old dirs belong to a publisher that may still be
    alive, and this function is called from read paths
    (:func:`latest_step`) that run concurrently with it.
    """
    if not os.path.isdir(ckpt_dir):
        return
    for name in sorted(os.listdir(ckpt_dir)):
        path = os.path.join(ckpt_dir, name)
        if re.fullmatch(r"step_\d+\.tmp(\..*)?", name):
            if _older_than(path, grace_s):
                shutil.rmtree(path, ignore_errors=True)
            continue
        m = re.fullmatch(r"(step_\d+)\.old\..*", name)
        if m and _older_than(path, grace_s):
            final = os.path.join(ckpt_dir, m.group(1))
            if (not os.path.exists(final)
                    and os.path.exists(os.path.join(path, "manifest.json"))):
                log.warning("recovering displaced checkpoint %s -> %s "
                            "(crash during publish)", name, m.group(1))
                try:
                    os.rename(path, final)
                except OSError:   # lost the race to another recoverer
                    pass
            else:
                shutil.rmtree(path, ignore_errors=True)


def quarantine(ckpt_dir: str, step: int) -> str:
    """Move a corrupt/poisoned step directory out of the restore path
    (renamed to ``step_N.corrupt*``, kept for post-mortem). Returns the
    quarantine path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    dst = d + ".corrupt"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{d}.corrupt.{n}"
    os.rename(d, dst)
    log.warning("quarantined checkpoint step %d -> %s", step,
                os.path.basename(dst))
    return dst


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Crash-atomically write a checkpoint; prune to the newest `keep`.

    Write path: unique tmp dir -> fsync files + tmp dir -> displace any
    existing final by rename -> rename tmp into place -> fsync parent ->
    delete the displaced dir. A crash at any point leaves either the old
    or the new checkpoint recoverable (``_clean_stale``).
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    _clean_stale(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp.", dir=ckpt_dir)
    unique = tmp.rsplit(".", 1)[-1]

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V":
            # extended dtypes (bfloat16): np.load round-trips them as raw
            # void fields, so store the bytes as a same-width uint view and
            # keep the true dtype in the manifest; restore views back. The
            # sha1 covers the raw bytes either way.
            arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append({
            "path": path, "key": key, "shape": list(arr.shape),
            "dtype": dtype_name,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)
    displaced = None
    if os.path.exists(final):
        # same-step re-save (e.g. trainer re-checkpointing at the same
        # batches_seen after a rollback): displace by rename, never rmtree
        # — the old checkpoint stays recoverable until the new one is live
        displaced = f"{final}.old.{unique}"
        os.rename(final, displaced)
    os.rename(tmp, final)
    _fsync_path(ckpt_dir)
    if displaced is not None:
        shutil.rmtree(displaced, ignore_errors=True)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """The newest step whose directory passes a light completeness check
    (parseable manifest + arrays file present). An incomplete/partial
    directory is quarantined and the previous step returned instead; a
    publish interrupted mid-rename is recovered first (``_clean_stale``)."""
    _clean_stale(ckpt_dir)
    steps = list_steps(ckpt_dir)
    while steps:
        step = steps.pop()
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                json.load(f)
            ok = os.path.exists(os.path.join(d, "arrays.npz"))
        except (OSError, ValueError):
            ok = False
        if ok:
            return step
        log.warning("checkpoint step %d is partial — quarantining and "
                    "falling back", step)
        try:
            quarantine(ckpt_dir, step)
        except OSError:
            # a concurrent publisher pruned/re-published the dir between
            # our check and the rename — nothing left to quarantine
            pass
    return None


def _read_manifest(d: str) -> Dict:
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        raise CorruptCheckpoint(f"unreadable manifest in {d}: {e}") from e


def peek(ckpt_dir: str, step: Optional[int] = None
         ) -> Tuple[Dict[str, Dict], Dict]:
    """Inspect a checkpoint without loading arrays: leaf metadata
    (``path -> {shape, dtype}``) plus the ``extra`` dict. Lets callers
    decide what structure to :func:`restore` into — e.g. the W2V trainer
    detecting a split-table (vocab-sharded) checkpoint and reassembling it
    for a replicated session, or vice versa."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _read_manifest(d)
    leaves = {l["path"]: {"shape": tuple(l["shape"]), "dtype": l["dtype"]}
              for l in manifest["leaves"]}
    return leaves, manifest["extra"]


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like` (arrays or
    ShapeDtypeStructs). `shardings` (optional pytree) re-places leaves for
    the current mesh — elastic resharding.

    With ``step=None`` a corrupt newest checkpoint is quarantined and the
    previous one restored instead (repeating as needed); an explicit
    ``step`` that turns out corrupt is quarantined and
    :class:`CorruptCheckpoint` re-raised so the caller can pick the
    fallback itself.
    """
    if step is not None:
        try:
            return _restore_step(ckpt_dir, step, tree_like, shardings,
                                 verify)
        except CorruptCheckpoint:
            quarantine(ckpt_dir, step)
            raise
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    while steps:
        s = steps.pop()
        try:
            return _restore_step(ckpt_dir, s, tree_like, shardings, verify)
        except CorruptCheckpoint as e:
            log.warning("checkpoint step %d corrupt (%s) — quarantining "
                        "and falling back", s, e)
            quarantine(ckpt_dir, s)
    raise FileNotFoundError(
        f"no readable checkpoints under {ckpt_dir} (all quarantined)")


def _restore_step(ckpt_dir: str, step: int, tree_like: Any,
                  shardings: Any, verify: bool) -> Tuple[Any, Dict]:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _read_manifest(d)
    try:
        data = np.load(os.path.join(d, "arrays.npz"))
    except (OSError, ValueError, zipfile.BadZipFile, EOFError) as e:
        raise CorruptCheckpoint(f"unreadable arrays.npz in {d}: {e}") from e
    by_path = {l["path"]: l for l in manifest["leaves"]}

    want = _flatten_with_paths(tree_like)
    shard_flat = (None if shardings is None
                  else [s for _, s in _flatten_with_paths(shardings)])
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for i, (path, like) in enumerate(want):
        meta = by_path.get(path)
        if meta is None:
            raise KeyError(f"checkpoint {d} missing leaf {path!r}")
        try:
            # a truncated zip member surfaces here, not at np.load (lazy)
            arr = data[meta["key"]]
        except (KeyError, OSError, ValueError, zipfile.BadZipFile,
                EOFError, zlib.error) as e:
            raise CorruptCheckpoint(
                f"unreadable leaf {path!r} in {d}: {e}") from e
        if verify and hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
            raise CorruptCheckpoint(f"checksum mismatch for {path!r} in {d}")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {path!r}: ckpt {arr.shape} vs "
                f"model {like.shape}")
        if str(arr.dtype) != meta["dtype"]:
            # extended dtypes stored as uint views (or legacy raw-void
            # loads): reinterpret to the manifest's true dtype before any
            # value conversion
            true_dt = np_dtype(meta["dtype"])
            if true_dt.itemsize == arr.dtype.itemsize:
                arr = arr.view(true_dt)
        arr = arr.astype(like.dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
