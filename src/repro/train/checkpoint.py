"""Fault-tolerant checkpointing: atomic, sharded-aware, reshard-on-load.

Layout (one directory per step):
    <dir>/step_000100.tmp/...   (written first)
    <dir>/step_000100/          (atomic rename when complete)
        manifest.json           (tree structure, shapes, dtypes, checksums)
        arrays.npz              (flattened leaves)

Restore works onto ANY mesh/sharding (elastic restarts): arrays are loaded
host-side and re-placed with `jax.device_put` against the target shardings —
the resharding path a 1000-node deployment needs when the surviving device
set changes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class PipelineCursor:
    """Host-pipeline position stored with every W2V checkpoint.

    Because batching randomness is keyed by ``(seed, epoch, batch_index)``
    (DESIGN.md §4.1), this pair is the *complete* input-pipeline state: on
    resume the pipeline fast-forwards with ``skip_batches=epoch_batch`` and
    reproduces the exact remainder of the interrupted epoch — for any
    ``prefetch_workers`` count, including one different from the run that
    wrote the checkpoint. ``prefetch_workers`` is recorded for provenance
    only, never replayed.
    """
    epoch: int = 0
    epoch_batch: int = 0        # batches already trained in `epoch`
    prefetch_workers: int = 0   # worker count of the writing run (info only)

    def to_extra(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "epoch_batch": self.epoch_batch,
                "prefetch_workers": self.prefetch_workers}

    @classmethod
    def from_extra(cls, extra: Dict[str, Any]) -> "PipelineCursor":
        return cls(epoch=int(extra.get("epoch", 0)),
                   epoch_batch=int(extra.get("epoch_batch", 0)),
                   prefetch_workers=int(extra.get("prefetch_workers", 0)))


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            elif hasattr(k, "name"):
                keys.append(str(k.name))
            else:
                keys.append(str(k))
        out.append(("/".join(keys), leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest `keep`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append({
            "path": path, "key": key, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        })
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def peek(ckpt_dir: str, step: Optional[int] = None
         ) -> Tuple[Dict[str, Dict], Dict]:
    """Inspect a checkpoint without loading arrays: leaf metadata
    (``path -> {shape, dtype}``) plus the ``extra`` dict. Lets callers
    decide what structure to :func:`restore` into — e.g. the W2V trainer
    detecting a split-table (vocab-sharded) checkpoint and reassembling it
    for a replicated session, or vice versa."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {l["path"]: {"shape": tuple(l["shape"]), "dtype": l["dtype"]}
              for l in manifest["leaves"]}
    return leaves, manifest["extra"]


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like` (arrays or
    ShapeDtypeStructs). `shardings` (optional pytree) re-places leaves for
    the current mesh — elastic resharding."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    by_path = {l["path"]: l for l in manifest["leaves"]}

    want = _flatten_with_paths(tree_like)
    shard_flat = (None if shardings is None
                  else [s for _, s in _flatten_with_paths(shardings)])
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for i, (path, like) in enumerate(want):
        meta = by_path.get(path)
        if meta is None:
            raise KeyError(f"checkpoint {d} missing leaf {path!r}")
        arr = data[meta["key"]]
        if verify and hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
            raise IOError(f"checksum mismatch for {path!r} in {d}")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {path!r}: ckpt {arr.shape} vs "
                f"model {like.shape}")
        arr = arr.astype(like.dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
