"""Supervised, self-healing W2V training (DESIGN.md §9).

:class:`TrainSupervisor` drives :meth:`TrainSession.stream` under
``run_with_recovery`` + ``Watchdog``: any step failure — an exception out
of the kernel or pipeline, a :class:`StepTimeout`, a failed table health
probe — rolls the session back to the latest good checkpoint
(``TrainSession.restore_latest``) and replays. Because batching randomness
is keyed by ``(corpus, cfg, epoch, batch_index)`` and the checkpoint
carries the exact :class:`PipelineCursor`, the replayed stream is
bit-identical to the uninterrupted one: a supervised run that survives
faults ends with exactly the tables a fault-free run produces
(``tools/chaos.py`` pins this by digest).

The health guard is a cheap device-side probe every ``health_every``
trained batches: one ``max(|table|)`` reduce per table. Non-finite values
or a norm blow-up raise :class:`HealthError`, which recovery treats like
any step failure — except that with ``skip_poison=True`` the offending
batch is marked in ``session.poison_skip`` so the replay excises it
(counters advance, tables untouched; counted and logged, never silent).
Skip identification assumes ``health_every=1`` — with a coarser probe any
of the last ``health_every`` batches may be the poison one, so the
supervisor refuses the combination. A restored checkpoint is probed too:
one that itself fails health is quarantined and the fallback continues
further back.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import time
from typing import Iterator, Optional

import jax.numpy as jnp

from repro.train.resilience import RetryPolicy, Watchdog, run_with_recovery

log = logging.getLogger("repro.supervisor")


class HealthError(RuntimeError):
    """A table health probe failed: non-finite values or ``max(|x|)``
    above the divergence bound."""


@dataclasses.dataclass
class SupervisorReport:
    """What one supervised run survived — the chaos harness's and
    ``bench_resilience``'s currency.

    ``restarts`` counts recovery invocations (one per step failure);
    ``rollbacks`` counts checkpoint restores, which can exceed
    ``restarts`` when a restored checkpoint itself fails the health probe
    and the fallback walks further back. ``recovery_seconds`` is total
    wall time inside recovery (close stream, restore, reopen).
    """
    restarts: int = 0
    rollbacks: int = 0
    health_failures: int = 0
    timeouts: int = 0
    batches_skipped: int = 0
    ckpt_quarantined: int = 0    # restored-but-unhealthy checkpoints
    recovery_seconds: float = 0.0
    batches: int = 0             # metrics consumed, replays included


class TrainSupervisor:
    """Run a :class:`TrainSession` to completion through faults.

    Parameters
    ----------
    max_restarts / backoff_s / reset_after : the :class:`RetryPolicy`.
        ``reset_after > 0`` refills the budget after that many
        consecutive good batches, so sparse failures over a long run
        never exhaust a budget sized for bursts.
    step_timeout_s : watchdog bound on a single batch (0 disables). The
        watchdog detects the overrun when the step returns; a genuinely
        hung device call is surfaced by the pipeline's own bounded polls.
    health_every : probe the tables every N trained batches (0 disables).
    norm_bound : ``max(|table|)`` above this raises :class:`HealthError`.
    skip_poison : on a health failure, mark the offending batch in
        ``session.poison_skip`` so the replay skips it. Requires
        ``health_every == 1``.
    epochs / max_batches : forwarded to ``stream``; ``max_batches`` is a
        *global* position (``state.batches_seen``), so replayed batches
        are not double-counted against it.
    """

    def __init__(self, session, *,
                 max_restarts: int = 3,
                 backoff_s: float = 0.05,
                 reset_after: int = 0,
                 step_timeout_s: float = 0.0,
                 health_every: int = 0,
                 norm_bound: float = 1e4,
                 skip_poison: bool = False,
                 epochs: Optional[int] = None,
                 max_batches: Optional[int] = None):
        if skip_poison and health_every != 1:
            raise ValueError(
                "skip_poison requires health_every=1: a coarser probe "
                "cannot attribute the failure to one batch")
        self.session = session
        self.policy = RetryPolicy(max_restarts=max_restarts,
                                  backoff_s=backoff_s,
                                  reset_after=reset_after)
        self.step_timeout_s = step_timeout_s
        self.health_every = health_every
        self.norm_bound = norm_bound
        self.skip_poison = skip_poison
        self.epochs = epochs
        self.max_batches = max_batches
        self.report = SupervisorReport()
        self._it: Optional[Iterator] = None
        self._finished = False
        self._since_probe = 0

    # -- health probe --------------------------------------------------------
    def _probe(self) -> None:
        """One ``max(|x|)`` reduce per table: NaN/Inf propagate through
        max, so a single float read detects both corruption and
        divergence."""
        for name, arr in self.session.state.params().items():
            m = float(jnp.max(jnp.abs(arr)))
            if not math.isfinite(m):
                raise HealthError(f"non-finite values in table {name!r}")
            if m > self.norm_bound:
                raise HealthError(
                    f"divergence in table {name!r}: max|x| = {m:.3g} > "
                    f"bound {self.norm_bound:g}")

    def _healthy(self) -> bool:
        try:
            self._probe()
            return True
        except HealthError:
            return False

    # -- stream plumbing -----------------------------------------------------
    def _remaining(self) -> Optional[int]:
        if self.max_batches is None:
            return None
        return max(0, self.max_batches - self.session.state.batches_seen)

    def _open(self) -> None:
        remaining = self._remaining()
        if remaining == 0:
            self._finished = True
            return
        self._it = self.session.stream(epochs=self.epochs,
                                       max_batches=remaining)

    def _close(self) -> None:
        if self._it is not None:
            self._it.close()
            self._it = None

    # -- the supervised loop -------------------------------------------------
    def _step(self, step: int) -> None:
        if self._it is None:
            self._open()
            if self._finished:
                return
        guard = (Watchdog(self.step_timeout_s) if self.step_timeout_s
                 else contextlib.nullcontext())
        with guard:
            metrics = next(self._it, None)
        if metrics is None:
            self._finished = True
            return
        self.report.batches += 1
        if self.health_every:
            self._since_probe += 1
            if self._since_probe >= self.health_every:
                self._since_probe = 0
                self._probe()

    def _recover(self, step: int, exc: BaseException) -> int:
        from repro.train.resilience import StepTimeout
        t0 = time.perf_counter()
        self.report.restarts += 1
        if isinstance(exc, HealthError):
            self.report.health_failures += 1
            if self.skip_poison:
                s = self.session.state
                key = (s.epoch, s.epoch_batch - 1)
                self.session.poison_skip.add(key)
                log.warning("marking poison batch %s for skip on replay",
                            key)
        if isinstance(exc, StepTimeout):
            self.report.timeouts += 1
        self._close()
        self._since_probe = 0
        while True:
            restored = self.session.restore_latest()
            self.report.rollbacks += 1
            if restored is None or self._healthy():
                break
            # the checkpoint itself is poisoned (e.g. saved after the
            # corruption landed) — quarantine and fall back further
            from repro.train import checkpoint as ckpt
            ckpt.quarantine(self.session.ckpt_dir, restored)
            self.report.ckpt_quarantined += 1
            log.warning("restored checkpoint step %d fails the health "
                        "probe — quarantined, falling back", restored)
        log.warning("recovered from %r: rolled back to step %s",
                    exc, restored)
        self.report.recovery_seconds += time.perf_counter() - t0
        return step

    def run(self):
        """Drain the session through faults; returns the final
        :class:`TrainState`. Raises only when the restart budget is
        exhausted (the last failure propagates)."""
        self.report = SupervisorReport()
        self._finished = False
        self._it = None
        try:
            run_with_recovery(self._step, start_step=0,
                              on_failure=self._recover,
                              policy=self.policy,
                              should_stop=lambda: self._finished)
        finally:
            self._close()
        self.report.batches_skipped = self.session.batches_skipped
        return self.session.state
