"""Optimizers (manual, shard-preserving): AdamW for LMs, SGD for W2V.

State pytrees mirror the parameter pytree, so `param_shardings` applies to
optimizer state verbatim (ZeRO: state shards with the FSDP'd parameters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # scalar int32
    m: Any                    # pytree like params (f32)
    v: Any                    # pytree like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_init(params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: AdamWState) -> Tuple[Any, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (jax.tree.unflatten(treedef, new_p),
            AdamWState(step=step,
                       m=jax.tree.unflatten(treedef, new_m),
                       v=jax.tree.unflatten(treedef, new_v)))
