"""Fault-tolerance utilities: retry-with-restore, watchdog, straggler
monitor, failure injection.

On a real multi-pod deployment these wrap the per-step execution: a step
that raises (device failure, preemption) triggers restore-from-checkpoint
and (via `repro.distributed.elastic`) a mesh rebuild over the surviving
device set. The logic is hardware-agnostic and fully unit-tested on CPU via
`FailureInjector`. The W2V path drives these through
``repro.train.supervisor.TrainSupervisor`` (DESIGN.md §9); the LM substrate
through ``repro.train.loop.Trainer``.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Callable, Dict, List, Optional

log = logging.getLogger("repro.resilience")


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class RetryPolicy:
    """Restart budget for :func:`run_with_recovery`.

    ``reset_after > 0`` refills the budget (and resets the backoff) after
    that many *consecutive* successful steps: a week-long run with sparse,
    unrelated failures never exhausts a budget sized for failure *bursts*.
    ``reset_after = 0`` keeps the budget cumulative over the whole run.
    """
    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0
    reset_after: int = 0


def run_with_recovery(step_fn: Callable[[int], None], *,
                      start_step: int, end_step: Optional[int] = None,
                      on_failure: Callable[[int, BaseException], int],
                      policy: RetryPolicy = RetryPolicy(),
                      should_stop: Optional[Callable[[], bool]] = None
                      ) -> int:
    """Drive `step_fn(step)` from start to end; on exception consult
    `on_failure(step, exc) -> resume_step` (typically: restore checkpoint,
    rebuild mesh, return the restored step). Returns the final step.

    ``end_step=None`` runs until ``should_stop()`` goes true — the mode for
    streaming workloads whose step count isn't known up front (the W2V
    supervisor drains a pipeline of unknown length). At least one of
    ``end_step`` / ``should_stop`` must be given.
    """
    if end_step is None and should_stop is None:
        raise ValueError("run_with_recovery needs end_step or should_stop")
    step = start_step
    restarts = 0
    successes = 0          # consecutive, for the reset_after budget refill
    backoff = policy.backoff_s
    while end_step is None or step < end_step:
        if should_stop is not None and should_stop():
            break
        try:
            step_fn(step)
            step += 1
            successes += 1
            if (policy.reset_after and restarts
                    and successes >= policy.reset_after):
                log.info("restart budget refilled after %d consecutive "
                         "good steps (%d restart(s) forgiven)",
                         successes, restarts)
                restarts = 0
                backoff = policy.backoff_s
        except KeyboardInterrupt:
            raise
        except BaseException as e:  # noqa: BLE001
            successes = 0
            restarts += 1
            if restarts > policy.max_restarts:
                log.error("step %d failed %d times — giving up", step,
                          restarts)
                raise
            log.warning("step %d failed (%r); recovering (restart %d/%d)",
                        step, e, restarts, policy.max_restarts)
            time.sleep(backoff)
            backoff *= policy.backoff_mult
            step = on_failure(step, e)
    return step


class Watchdog:
    """Raises (in the waiting thread) if a step exceeds `timeout_s` —
    detects hung collectives / dead hosts. Use as a context manager around
    the blocking step call.

    If the step *also* raised, the timeout is not swallowed: a
    :class:`StepTimeout` chained from the step's exception propagates, so
    recovery sees both facts. Non-``Exception`` escapes
    (KeyboardInterrupt/SystemExit) win over the timeout and propagate
    unchanged (logged).
    """

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def _fire(self):
        self.fired = True
        if self.on_timeout:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        assert self._timer is not None
        self._timer.cancel()
        if not self.fired:
            return False
        if exc_type is None:
            raise StepTimeout(f"step exceeded {self.timeout_s}s")
        if issubclass(exc_type, Exception):
            raise StepTimeout(
                f"step exceeded {self.timeout_s}s (and also raised "
                f"{exc!r})") from exc
        log.warning("watchdog fired during %r — propagating it unchanged",
                    exc)
        return False


class StragglerMonitor:
    """EMA-based step-time tracker. On real pods each host reports its step
    time; hosts persistently slower than `threshold` × median are flagged
    for replacement (the scheduler's straggler-mitigation hook).

    Decay convention (documented and tested): the first report *seeds* the
    EMA with the raw sample; every later report updates it as
    ``ema' = decay * ema + (1 - decay) * sample`` — ``decay`` weights the
    history, ``1 - decay`` the new sample.

    ``window > 0`` evicts hosts not heard from within the last ``window``
    reports (counted across *all* hosts): a host that left the job stops
    dragging the median. Size it well above the host count — e.g.
    ``4 × n_hosts`` tolerates a few missed heartbeats before eviction.
    """

    def __init__(self, decay: float = 0.9, threshold: float = 1.5,
                 window: int = 0):
        self.decay = decay
        self.threshold = threshold
        self.window = window
        self.times: Dict[str, float] = {}
        self._last_report: Dict[str, int] = {}
        self._n_reports = 0

    def report(self, host: str, seconds: float) -> None:
        prev = self.times.get(host)
        self.times[host] = (seconds if prev is None
                            else self.decay * prev
                            + (1 - self.decay) * seconds)
        self._n_reports += 1
        self._last_report[host] = self._n_reports
        if self.window:
            gone = [h for h, n in self._last_report.items()
                    if self._n_reports - n >= self.window]
            for h in gone:
                log.info("evicting silent host %s (last report %d of %d)",
                         h, self._last_report[h], self._n_reports)
                del self.times[h]
                del self._last_report[h]

    def median(self) -> float:
        vals = sorted(self.times.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> List[str]:
        med = self.median()
        if med == 0.0:
            return []
        return [h for h, t in self.times.items()
                if t > self.threshold * med]


class FailureInjector:
    """Deterministic failure injection for tests: raises on the given
    steps (once each)."""

    def __init__(self, fail_steps: List[int],
                 exc_factory: Callable[[], BaseException] = RuntimeError):
        self.fail_steps = set(fail_steps)
        self.exc_factory = exc_factory

    def check(self, step: int) -> None:
        if step in self.fail_steps:
            self.fail_steps.remove(step)
            raise self.exc_factory(f"injected failure at step {step}")
