"""Fault-tolerance utilities: retry-with-restore, watchdog, straggler
monitor, failure injection.

On a real multi-pod deployment these wrap the per-step execution: a step
that raises (device failure, preemption) triggers restore-from-checkpoint
and (via `repro.distributed.elastic`) a mesh rebuild over the surviving
device set. The logic is hardware-agnostic and fully unit-tested on CPU via
`FailureInjector`.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Callable, Dict, List, Optional

log = logging.getLogger("repro.resilience")


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0


def run_with_recovery(step_fn: Callable[[int], None], *,
                      start_step: int, end_step: int,
                      on_failure: Callable[[int, BaseException], int],
                      policy: RetryPolicy = RetryPolicy()) -> int:
    """Drive `step_fn(step)` from start to end; on exception consult
    `on_failure(step, exc) -> resume_step` (typically: restore checkpoint,
    rebuild mesh, return the restored step). Returns the final step."""
    step = start_step
    restarts = 0
    backoff = policy.backoff_s
    while step < end_step:
        try:
            step_fn(step)
            step += 1
        except KeyboardInterrupt:
            raise
        except BaseException as e:  # noqa: BLE001
            restarts += 1
            if restarts > policy.max_restarts:
                log.error("step %d failed %d times — giving up", step,
                          restarts)
                raise
            log.warning("step %d failed (%r); recovering (restart %d/%d)",
                        step, e, restarts, policy.max_restarts)
            time.sleep(backoff)
            backoff *= policy.backoff_mult
            step = on_failure(step, e)
    return step


class Watchdog:
    """Raises (in the waiting thread) if a step exceeds `timeout_s` —
    detects hung collectives / dead hosts. Use as a context manager around
    the blocking step call."""

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def _fire(self):
        self.fired = True
        if self.on_timeout:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        assert self._timer is not None
        self._timer.cancel()
        if self.fired and exc[0] is None:
            raise StepTimeout(f"step exceeded {self.timeout_s}s")
        return False


class StragglerMonitor:
    """EMA-based step-time tracker. On real pods each host reports its step
    time; hosts persistently slower than `threshold` × median are flagged
    for replacement (the scheduler's straggler-mitigation hook)."""

    def __init__(self, ema: float = 0.9, threshold: float = 1.5):
        self.ema = ema
        self.threshold = threshold
        self.times: Dict[str, float] = {}

    def report(self, host: str, seconds: float) -> None:
        prev = self.times.get(host)
        self.times[host] = (seconds if prev is None
                            else self.ema * prev + (1 - self.ema) * seconds)

    def median(self) -> float:
        vals = sorted(self.times.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> List[str]:
        med = self.median()
        if med == 0.0:
            return []
        return [h for h, t in self.times.items()
                if t > self.threshold * med]


class FailureInjector:
    """Deterministic failure injection for tests: raises on the given
    steps (once each)."""

    def __init__(self, fail_steps: List[int],
                 exc_factory: Callable[[], BaseException] = RuntimeError):
        self.fail_steps = set(fail_steps)
        self.exc_factory = exc_factory

    def check(self, step: int) -> None:
        if step in self.fail_steps:
            self.fail_steps.remove(step)
            raise self.exc_factory(f"injected failure at step {step}")
