"""Deterministic chaos schedules: scripted faults against a supervised run.

A :class:`ChaosSchedule` names *which fault fires at which global batch*
(``state.batches_seen`` — deterministic, so the same schedule replays the
same faults). :func:`run_chaos` executes it end to end: train a fault-free
baseline, then the same workload under :class:`TrainSupervisor` with the
faults injected, and compare final table digests. Because batching
randomness is keyed and recovery replays from exact pipeline cursors
(DESIGN.md §9), the supervised run must end **bit-identical** to the
baseline — the harness's pass/fail is digest equality, not "it didn't
crash".

Fault kinds (all fire from the ``on_batch`` callback, i.e. *after* the
batch trained and any due checkpoint was published — so a checkpoint is
never poisoned by the fault scheduled at its own step):

  * ``fail_steps``        — raise out of the step (FailureInjector-style)
  * ``kill_worker_at``    — SIGKILL a live process-pool prefetch worker
  * ``truncate_ckpt_at``  — truncate the newest checkpoint's arrays.npz
  * ``nan_at``            — overwrite a table cell with NaN

Each fault fires exactly once (replays after a rollback do not re-fire),
which keeps the schedule a fixed fault *set* rather than a rate.
``tools/chaos.py`` is the CLI; ``benchmarks/bench_resilience.py`` turns
the result dict into trajectory rows for the CI perf gate.
"""
from __future__ import annotations

import dataclasses
import glob
import hashlib
import logging
import os
import signal
import tempfile
import time
from typing import Dict, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.chaos")


def table_digest(state) -> str:
    """sha1 over the (device-fetched) embedding tables — the same digest
    the launch CLI prints as ``final_digest``."""
    h = hashlib.sha1()
    h.update(np.asarray(state.w_in).tobytes())
    h.update(np.asarray(state.w_out).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A deterministic fault script plus the tiny workload it runs on."""
    fail_steps: Tuple[int, ...] = ()
    kill_worker_at: Tuple[int, ...] = ()
    truncate_ckpt_at: Tuple[int, ...] = ()
    nan_at: Tuple[int, ...] = ()
    max_batches: int = 10
    epochs: int = 2
    ckpt_every: int = 2
    max_restarts: int = 4
    health_every: int = 1
    prefetch_workers: int = 2
    prefetch_mode: str = "process"   # worker kills need real processes

    @property
    def n_faults(self) -> int:
        return (len(self.fail_steps) + len(self.kill_worker_at)
                + len(self.truncate_ckpt_at) + len(self.nan_at))


# The ``ci`` schedule is the acceptance bar: >=1 injected step exception,
# >=1 killed prefetch worker, >=1 truncated checkpoint (plus a NaN), all
# inside a 10-batch run that crosses an epoch boundary (5 batches/epoch).
SCHEDULES: Dict[str, ChaosSchedule] = {
    "ci": ChaosSchedule(fail_steps=(3, 5), kill_worker_at=(2,),
                        truncate_ckpt_at=(4,), nan_at=(6,)),
    "smoke": ChaosSchedule(fail_steps=(3,), max_batches=6,
                           prefetch_workers=0, prefetch_mode="thread"),
    "none": ChaosSchedule(),
}


class ChaosMonkey:
    """Fires a :class:`ChaosSchedule` from a session's ``on_batch`` hook."""

    def __init__(self, schedule: ChaosSchedule, ckpt_dir: str):
        self.schedule = schedule
        self.ckpt_dir = ckpt_dir
        self.pipeline = None          # bound after session construction
        self.fired: set = set()
        self.workers_killed = 0
        self.ckpts_truncated = 0

    def bind(self, pipeline) -> None:
        self.pipeline = pipeline

    def _once(self, kind: str, n: int) -> bool:
        if (kind, n) in self.fired:
            return False
        self.fired.add((kind, n))
        return True

    def on_batch(self, state) -> None:
        n = state.batches_seen
        if n in self.schedule.nan_at and self._once("nan", n):
            log.warning("chaos: injecting NaN into w_in at batch %d", n)
            state.w_in = state.w_in.at[0, 0].set(float("nan"))
        if n in self.schedule.truncate_ckpt_at and self._once("trunc", n):
            self._truncate_newest(n)
        if n in self.schedule.kill_worker_at and self._once("kill", n):
            self._kill_worker(n)
        if n in self.schedule.fail_steps and self._once("fail", n):
            raise RuntimeError(f"chaos: injected failure at batch {n}")

    def _truncate_newest(self, n: int) -> None:
        from repro.train import checkpoint as ckpt
        steps = ckpt.list_steps(self.ckpt_dir)
        if not steps:
            log.warning("chaos: no checkpoint to truncate at batch %d", n)
            return
        path = os.path.join(self.ckpt_dir, f"step_{steps[-1]:08d}",
                            "arrays.npz")
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        self.ckpts_truncated += 1
        log.warning("chaos: truncated %s (%d -> %d bytes) at batch %d",
                    path, size, max(size // 2, 1), n)

    def _kill_worker(self, n: int) -> None:
        pids = (self.pipeline.worker_pids()
                if self.pipeline is not None
                and hasattr(self.pipeline, "worker_pids") else [])
        if not pids:
            log.warning("chaos: no process-pool worker to kill at batch %d "
                        "(thread mode?)", n)
            return
        os.kill(pids[0], signal.SIGKILL)
        self.workers_killed += 1
        log.warning("chaos: SIGKILLed prefetch worker pid %d at batch %d",
                    pids[0], n)


def _make_workload(schedule: ChaosSchedule):
    from repro.configs.w2v import smoke
    from repro.data.batching import BatchingPipeline
    from repro.data.corpus import synthetic_cluster_corpus

    # 300 sentences / 64 per batch -> 5 batches/epoch: a 10-batch schedule
    # crosses the epoch boundary, so mid-epoch AND cross-epoch rollbacks
    # are both exercised
    cfg = smoke(epochs=schedule.epochs, dim=32, sentences_per_batch=64,
                prefetch_workers=schedule.prefetch_workers,
                prefetch_mode=schedule.prefetch_mode)
    corpus = synthetic_cluster_corpus(n_clusters=4, words_per_cluster=8,
                                      n_sentences=300, mean_len=10, seed=0)
    vocab = BatchingPipeline(corpus, cfg).vocab
    return cfg, corpus, vocab


def run_chaos(schedule: ChaosSchedule, *,
              ckpt_dir: Optional[str] = None,
              backend: str = "jnp") -> Dict:
    """Run `schedule` end to end; returns the result/metrics dict.

    ``digest_match`` is the headline: the supervised faulted run's final
    tables are bit-identical to the fault-free baseline's.
    """
    from repro.core.trainer import TrainSession
    from repro.data.batching import BatchingPipeline
    from repro.data.prefetch import AsyncBatchingPipeline

    cfg, corpus, vocab = _make_workload(schedule)

    # fault-free baseline (sync pipeline: prefetch is bit-identical to it)
    base = TrainSession(BatchingPipeline(corpus, cfg, vocab=vocab), cfg,
                        backend=backend)
    base.train(max_batches=schedule.max_batches)
    baseline_digest = table_digest(base.state)

    owns_dir = ckpt_dir is None
    tmp = tempfile.mkdtemp(prefix="chaos_ckpt_") if owns_dir else ckpt_dir
    try:
        if schedule.prefetch_workers > 0:
            pipe = AsyncBatchingPipeline(corpus, cfg, vocab=vocab,
                                         workers=schedule.prefetch_workers,
                                         mode=schedule.prefetch_mode)
        else:
            pipe = BatchingPipeline(corpus, cfg, vocab=vocab)
        monkey = ChaosMonkey(schedule, tmp)
        sess = TrainSession(pipe, cfg, backend=backend, ckpt_dir=tmp,
                            ckpt_every=schedule.ckpt_every,
                            on_batch=monkey.on_batch)
        monkey.bind(pipe)
        t0 = time.perf_counter()
        sess.train_resilient(max_batches=schedule.max_batches,
                             max_restarts=schedule.max_restarts,
                             health_every=schedule.health_every,
                             backoff_s=0.01)
        wall = time.perf_counter() - t0
        report = sess.last_report
        final_digest = table_digest(sess.state)
        quarantined_dirs = len(glob.glob(os.path.join(tmp,
                                                      "step_*.corrupt*")))
        return {
            "baseline_digest": baseline_digest,
            "final_digest": final_digest,
            "digest_match": int(final_digest == baseline_digest),
            "batches_seen": sess.state.batches_seen,
            "restarts": report.restarts,
            "rollbacks": report.rollbacks,
            "health_failures": report.health_failures,
            "timeouts": report.timeouts,
            "batches_skipped": report.batches_skipped,
            "ckpt_quarantined": quarantined_dirs,
            "recovery_seconds": round(report.recovery_seconds, 4),
            "heals": getattr(pipe, "prefetch", None).heals
            if hasattr(pipe, "prefetch") else 0,
            "workers_killed": monkey.workers_killed,
            "ckpts_truncated": monkey.ckpts_truncated,
            "faults_fired": len(monkey.fired),
            "faults_scheduled": schedule.n_faults,
            "wall_seconds": round(wall, 3),
        }
    finally:
        if owns_dir:
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)
