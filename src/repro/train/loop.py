"""LM training loop: microbatching, checkpoint/restart, straggler + failure
handling. Works on any mesh (host mesh for tests, production mesh on pods).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import Rules, param_shardings
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamWConfig, AdamWState, adamw_init
from repro.train.resilience import (
    FailureInjector,
    RetryPolicy,
    StragglerMonitor,
    run_with_recovery,
)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    microbatches: int = 1
    log_every: int = 10
    step_timeout_s: float = 0.0          # 0 = no watchdog
    max_restarts: int = 3


def synthetic_lm_batches(cfg: ArchConfig, batch: int, seq: int,
                         seed: int = 0) -> Iterator[Dict]:
    """Deterministic synthetic token stream (per-step seeded)."""
    step = 0
    while True:
        rng = np.random.default_rng(seed + step)
        toks = rng.integers(0, cfg.vocab, (batch, seq + 1), dtype=np.int32)
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if cfg.prefix_len:
            out["prefix_embeds"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.prefix_len, cfg.d_model)),
                jnp.float32)
        yield out
        step += 1


class Trainer:
    def __init__(self, cfg: ArchConfig, opt: AdamWConfig, loop: LoopConfig,
                 mesh=None, batch_fn: Optional[Callable[[int], Dict]] = None,
                 batch: int = 8, seq: int = 128,
                 param_dtype=jnp.float32,
                 failure_injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.opt = opt
        self.loop = loop
        self.mesh = mesh
        self.monitor = StragglerMonitor()
        self.failure_injector = failure_injector
        self.history: list = []

        key = jax.random.PRNGKey(0)
        self.params = lm.init_params(cfg, key, param_dtype)
        self.opt_state = adamw_init(self.params)
        if mesh is not None:
            rules = Rules(mesh)
            p_sh = param_shardings(self.params, rules)
            self.params = jax.device_put(self.params, p_sh)
            self.opt_state = AdamWState(
                step=self.opt_state.step,
                m=jax.device_put(self.opt_state.m, p_sh),
                v=jax.device_put(self.opt_state.v, p_sh))
        self.step_fn = jax.jit(make_train_step(cfg, opt, loop.microbatches),
                               donate_argnums=(0, 1))
        if batch_fn is None:
            it = synthetic_lm_batches(cfg, batch, seq)
            batch_fn = lambda step: next(it)
        self.batch_fn = batch_fn
        self.start_step = 0
        if loop.ckpt_dir and ckpt.latest_step(loop.ckpt_dir) is not None:
            self._restore()

    # ------------------------------------------------------------------
    def _save(self, step: int) -> None:
        if not self.loop.ckpt_dir:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        ckpt.save(self.loop.ckpt_dir, step, tree, keep=self.loop.keep,
                  extra={"arch": self.cfg.name})
        log.info("checkpointed step %d", step)

    def _restore(self) -> int:
        tree_like = {"params": self.params, "opt": self.opt_state}
        tree, _ = ckpt.restore(self.loop.ckpt_dir, tree_like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.start_step = ckpt.latest_step(self.loop.ckpt_dir) or 0
        log.info("restored checkpoint at step %d", self.start_step)
        return self.start_step

    # ------------------------------------------------------------------
    def train(self) -> Dict:
        if self.loop.ckpt_dir:
            self._save(self.start_step)

        def one_step(step: int) -> None:
            if self.failure_injector is not None:
                self.failure_injector.check(step)
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            dt = time.perf_counter() - t0
            self.monitor.report("host0", dt)
            self.history.append(loss)
            if step % self.loop.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", step, loss, dt * 1e3)
            if (self.loop.ckpt_dir and (step + 1) % self.loop.ckpt_every == 0):
                self._save(step + 1)

        def on_failure(step: int, exc: BaseException) -> int:
            if self.loop.ckpt_dir:
                return self._restore()
            # no checkpointing: re-init optimizer step only, keep going
            return step

        final = run_with_recovery(
            one_step, start_step=self.start_step, end_step=self.loop.steps,
            on_failure=on_failure,
            policy=RetryPolicy(max_restarts=self.loop.max_restarts))
        if self.loop.ckpt_dir:
            self._save(final)
        return {"final_step": final, "losses": self.history,
                "stragglers": self.monitor.stragglers()}
