from repro.train.optim import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    lr_schedule,
)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "lr_schedule"]
