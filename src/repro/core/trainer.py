"""W2V training sessions: streaming steps, LR decay, Hogwild data
parallelism, checkpoint/resume, metrics callbacks.

:class:`TrainSession` owns everything around the kernel: the classic
linear LR schedule, the Hogwild mesh averaging of the paper's multi-GPU
future-work, periodic checkpointing with resume (``train.checkpoint`` —
atomic, reshard-on-load), and per-step metrics. The kernel itself is
reached exclusively through the engine API (``kernels.ops.sgns_update`` /
``kernels.registry``): the backend name is resolved once against the
registry at construction, so invalid combinations fail fast with the fix
spelled out rather than mid-epoch.

Single-device steps dispatch through ``sgns_update`` directly. The
multi-device path shards sentences over the ``data`` mesh axis under
``shard_map``; each device runs the resolved backend on its shard against
a local table replica (Hogwild — benign divergence) and replicas are
averaged by ``pmean``. The window-tiled path (``cfg.tile_windows > 1``)
composes with the mesh: the host tile schedule is built per sentence, so
sharding the batch's plan arrays along ``data`` hands every device
exactly the per-shard ``plan_tiles`` schedule, and the averaging is
unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.w2v import W2VConfig
from repro.data.batching import Batch, BatchingPipeline
from repro.kernels import ops, registry
from repro.kernels.registry import StepInputs


@dataclasses.dataclass
class TrainState:
    w_in: jax.Array
    w_out: jax.Array
    words_seen: int = 0
    batches_seen: int = 0
    epoch: int = 0
    epoch_batch: int = 0   # batches completed within the current epoch

    def params(self) -> Dict[str, jax.Array]:
        return {"w_in": self.w_in, "w_out": self.w_out}


@dataclasses.dataclass
class StepMetrics:
    """Per-batch metrics yielded by :meth:`TrainSession.stream`.

    ``fetch_seconds`` is the time the step loop spent *blocked waiting* for
    this batch from the host pipeline — the overlap-efficiency signal: with
    prefetch on it should collapse toward zero while the device stays busy.
    ``queue_depth`` is the async pipeline's ready-batch depth when this
    batch was taken (-1 for synchronous pipelines).
    """
    epoch: int
    batches_seen: int
    words_seen: int
    batch_words: int
    lr: float
    backend: str
    fetch_seconds: float = 0.0
    queue_depth: int = -1


def init_state(vocab_size: int, cfg: W2VConfig, seed: int = 0) -> TrainState:
    """Mikolov init: w_in ~ U(-0.5/d, 0.5/d), w_out = 0."""
    key = jax.random.PRNGKey(seed)
    d = cfg.dim
    w_in = (jax.random.uniform(key, (vocab_size, d), jnp.float32) - 0.5) / d
    w_out = jnp.zeros((vocab_size, d), jnp.float32)
    return TrainState(w_in=w_in, w_out=w_out)


class TrainSession:
    """A streaming W2V training session over a batching pipeline.

    Parameters
    ----------
    backend : registry name or ``"auto"``. Resolved once at construction
        (``cfg.tile_windows > 1`` selects the window-tiled family); bad
        names or invalid capability combinations raise immediately.
    mesh : optional device mesh with a ``data`` axis for Hogwild data
        parallelism. Composes with ``cfg.tile_windows > 1``.
    ckpt_dir / ckpt_every : when set, checkpoint every N batches (atomic,
        pruned) and — unless ``resume=False`` — restore the latest
        checkpoint at construction, continuing words/batches/epoch counts.
    on_batch / on_metrics : callbacks after every trained batch, receiving
        the :class:`TrainState` / :class:`StepMetrics` respectively.
    """

    def __init__(
        self,
        pipeline: BatchingPipeline,
        cfg: W2VConfig,
        backend: str = "auto",
        mesh: Optional[Mesh] = None,
        sync_every: int = 1,
        on_batch: Optional[Callable[[TrainState], None]] = None,
        on_metrics: Optional[Callable[[StepMetrics], None]] = None,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 0,
        resume: bool = True,
    ):
        self.pipeline = pipeline
        self.cfg = cfg
        # resolve once against the registry: invalid backend/capability
        # combinations (unknown name, TPU-only backend off-TPU, plan
        # mismatch) fail here, not mid-epoch. The *requested* name is kept
        # for dispatch so batches without a plan (T=1) can still resolve
        # their sequential variant
        self._requested_backend = backend
        self.backend = registry.resolve(backend,
                                        tiled=cfg.tile_windows > 1).name
        self.mesh = mesh
        self.sync_every = sync_every
        self.on_batch = on_batch
        self.on_metrics = on_metrics
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.state = init_state(pipeline.vocab.size, cfg, cfg.seed)
        self.total_words = max(1, pipeline.epoch_words * cfg.epochs)
        self.words_per_sec = 0.0
        self.fetch_seconds = 0.0   # cumulative wait on the host pipeline
        self.wall_seconds = 0.0    # last train() wall time
        self.resumed_step: Optional[int] = None
        self._resume_skip = 0
        if ckpt_dir and resume:
            self._maybe_resume()
        if mesh is not None and not registry.get(self.backend).supports_mesh:
            raise ValueError(
                f"backend {self.backend!r} does not support mesh sharding")
        # data-parallel update fns, built lazily per tile size (a batch
        # with a plan uses the tiled kernel family, one without the
        # sequential family — both compose with the mesh)
        self._dp_updates: Dict[int, Callable] = {}

    # -- learning-rate schedule (classic linear decay) ----------------------
    def _lr_at(self, words_seen: int) -> float:
        frac = 1.0 - words_seen / self.total_words
        return self.cfg.lr * max(frac, self.cfg.min_lr_frac)

    def current_lr(self) -> float:
        return self._lr_at(self.state.words_seen)

    # -- data-parallel Hogwild step ------------------------------------------
    def _dp_update(self, tile: int) -> Callable:
        """The sharded update for batches of tile size T (T=1: sequential
        backend). Sentences — and, for T>1, the per-sentence rows of the
        host tile schedule — shard over the ``data`` axis; each shard runs
        the kernel locally and replicas are pmean-averaged (Hogwild)."""
        fn = self._dp_updates.get(tile)
        if fn is not None:
            return fn
        from jax.experimental.shard_map import shard_map

        # T>1 resolves the tiled counterpart of the requested backend;
        # T=1 batches (no plan) resolve its sequential variant even when
        # cfg.tile_windows > 1 resolved a tiled name at construction
        be = registry.resolve(self._requested_backend, tiled=tile > 1)
        local = ops.traceable_update(be.name,
                                     ops.static_for(self.cfg, tile))

        def local_update(w_in, w_out, step: StepInputs):
            new_in, new_out = local(w_in, w_out, step)
            # Hogwild model averaging across the data axis
            return (jax.lax.pmean(new_in, "data"),
                    jax.lax.pmean(new_out, "data"))

        plan_spec = P("data") if tile > 1 else None
        step_specs = StepInputs(
            tokens=P("data"), negs=P("data"), lengths=P("data"), lr=P(),
            plan_uniq=plan_spec, plan_scatter=plan_spec,
            plan_ucount=plan_spec, plan_strict=plan_spec)
        sharded = shard_map(
            local_update, mesh=self.mesh,
            in_specs=(P(), P(), step_specs),
            out_specs=(P(), P()),
            check_rep=False,
        )
        fn = jax.jit(sharded, donate_argnums=(0, 1))
        self._dp_updates[tile] = fn
        return fn

    # -- train ---------------------------------------------------------------
    def train_batch(self, batch: Batch,
                    step: Optional[StepInputs] = None,
                    fetch_seconds: float = 0.0) -> StepMetrics:
        """Train one batch. ``step`` may be a pre-built (already
        device_put) :class:`StepInputs` from the prefetch path — its lr was
        computed from the projected word count, which equals
        ``current_lr()`` exactly because word counts are known host-side
        ahead of training."""
        lr = self.current_lr()
        if step is None:
            step = batch.step_inputs(lr)
        if self.mesh is not None:
            self.state.w_in, self.state.w_out = self._dp_update(step.tile)(
                self.state.w_in, self.state.w_out, step)
        else:
            self.state.w_in, self.state.w_out = ops.sgns_update(
                self.state.w_in, self.state.w_out, step, self.cfg,
                backend=self._requested_backend)
        self.state.words_seen += batch.n_words
        self.state.batches_seen += 1
        self.state.epoch_batch += 1
        self.fetch_seconds += fetch_seconds
        metrics = StepMetrics(
            epoch=self.state.epoch, batches_seen=self.state.batches_seen,
            words_seen=self.state.words_seen, batch_words=batch.n_words,
            lr=lr, backend=self.backend, fetch_seconds=fetch_seconds,
            queue_depth=getattr(self.pipeline, "ready_depth", -1))
        if (self.ckpt_dir and self.ckpt_every
                and self.state.batches_seen % self.ckpt_every == 0):
            self.save_checkpoint()
        if self.on_batch is not None:
            self.on_batch(self.state)
        if self.on_metrics is not None:
            self.on_metrics(metrics)
        return metrics

    def _prepared(self, batch_iter: Iterator[Batch]
                  ) -> Iterator[tuple]:
        """Lift host batches onto the device one step ahead (double
        buffering): batch k+1's ``jax.device_put`` is issued while the
        device still computes batch k, so host→device transfer overlaps
        the update. lr for batch k+1 is exact, not estimated — it depends
        only on cumulative host-side word counts."""
        projected = self.state.words_seen
        try:
            for batch in batch_iter:
                lr = self._lr_at(projected)
                step = batch.step_inputs(lr)   # async transfer starts here
                projected += batch.n_words
                yield batch, step
        finally:
            close = getattr(batch_iter, "close", None)
            if close is not None:
                close()

    def stream(self, epochs: Optional[int] = None,
               max_batches: Optional[int] = None) -> Iterator[StepMetrics]:
        """Stream the session: train batch by batch, yielding metrics after
        each. Resumed sessions continue from the checkpointed position —
        randomness is keyed by (epoch, batch index), so the pipeline's
        ``skip_batches`` fast-forward reproduces the exact remainder of the
        interrupted epoch without re-finalizing (or re-counting) anything.

        With ``cfg.prefetch_workers > 0`` the loop double-buffers: while
        the device updates batch k, the async pipeline finalizes batches
        k+1.. in its workers and batch k+1's device transfer is in flight.
        """
        epochs = epochs if epochs is not None else self.cfg.epochs
        pad_len = self.cfg.resolved_pad_len
        n_batches = 0
        skip = self._resume_skip  # >0 only right after a mid-epoch restore
        self._resume_skip = 0
        for ep in range(min(self.state.epoch, epochs), epochs):
            self.state.epoch = ep
            if not skip:
                self.state.epoch_batch = 0
            it = self.pipeline.batches(pad_len=pad_len, epoch=ep,
                                       skip_batches=skip)
            skip = 0
            prepared = self._prepared(it)
            try:
                t0 = time.perf_counter()
                cur = next(prepared, None)
                wait = time.perf_counter() - t0
                while cur is not None:
                    batch, step = cur
                    metrics = self.train_batch(batch, step=step,
                                               fetch_seconds=wait)
                    n_batches += 1
                    done = (max_batches is not None
                            and n_batches >= max_batches)
                    if done:
                        yield metrics
                        return
                    # with prefetch, pull batch k+1 *before* yielding: the
                    # update just dispatched is still running on the device
                    # while the host pipeline hands over (or finishes) k+1
                    t0 = time.perf_counter()
                    cur = next(prepared, None)
                    wait = time.perf_counter() - t0
                    yield metrics
            finally:
                prepared.close()

    def train(self, epochs: Optional[int] = None,
              max_batches: Optional[int] = None) -> TrainState:
        """Drain :meth:`stream` to completion; returns the final state."""
        words0 = self.state.words_seen
        self.fetch_seconds = 0.0
        t0 = time.perf_counter()
        for _ in self.stream(epochs=epochs, max_batches=max_batches):
            pass
        jax.block_until_ready(self.state.w_in)
        dt = time.perf_counter() - t0
        self.wall_seconds = dt
        self.words_per_sec = ((self.state.words_seen - words0) / dt
                              if dt else 0.0)
        return self.state

    @property
    def device_busy_frac(self) -> float:
        """Fraction of the last ``train()`` wall time NOT spent blocked on
        the host pipeline — the overlap-efficiency headline: ~host-bound
        when low, compute-bound (the paper's goal) when near 1."""
        if not self.wall_seconds:
            return 0.0
        return max(0.0, 1.0 - self.fetch_seconds / self.wall_seconds)

    # -- checkpoint / resume --------------------------------------------------
    def save_checkpoint(self) -> str:
        """Atomically checkpoint tables + progress counters + the host
        pipeline cursor (exact mid-epoch resume, prefetch or not)."""
        from repro.train import checkpoint as ckpt
        assert self.ckpt_dir, "TrainSession has no ckpt_dir"
        cursor = ckpt.PipelineCursor(
            epoch=self.state.epoch, epoch_batch=self.state.epoch_batch,
            prefetch_workers=self.cfg.prefetch_workers)
        return ckpt.save(
            self.ckpt_dir, self.state.batches_seen, self.state.params(),
            extra={"words_seen": self.state.words_seen,
                   "batches_seen": self.state.batches_seen,
                   "backend": self.backend, **cursor.to_extra()})

    def _maybe_resume(self) -> None:
        from repro.train import checkpoint as ckpt
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return
        like = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in self.state.params().items()}
        tree, extra = ckpt.restore(self.ckpt_dir, like, step=step)
        self.state.w_in = tree["w_in"]
        self.state.w_out = tree["w_out"]
        self.state.words_seen = int(extra.get("words_seen", 0))
        self.state.batches_seen = int(extra.get("batches_seen", step))
        cursor = ckpt.PipelineCursor.from_extra(extra)
        self.state.epoch = cursor.epoch
        self.state.epoch_batch = cursor.epoch_batch
        self._resume_skip = cursor.epoch_batch
        self.resumed_step = step

    # -- inference helpers ----------------------------------------------------
    def embeddings(self) -> np.ndarray:
        return np.asarray(self.state.w_in)

    def nearest(self, word_id: int, k: int = 5) -> np.ndarray:
        e = self.embeddings()
        e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-12)
        sims = e @ e[word_id]
        sims[word_id] = -np.inf
        return np.argsort(-sims)[:k]


# Backwards-compatible name: the session IS the trainer.
W2VTrainer = TrainSession
