"""W2V training sessions: streaming steps, LR decay, Hogwild data
parallelism, checkpoint/resume, metrics callbacks.

:class:`TrainSession` owns everything around the kernel: the classic
linear LR schedule, the Hogwild mesh averaging of the paper's multi-GPU
future-work, periodic checkpointing with resume (``train.checkpoint`` —
atomic, reshard-on-load), and per-step metrics. The kernel itself is
reached exclusively through the engine API (``kernels.ops.step`` /
``kernels.registry``): the session's :class:`TableSpec` (from
``cfg.tables`` / the legacy knobs) is resolved once against the registry
at construction, so invalid combinations — unknown backend, TPU-only
backend off-TPU, storage dtypes the backend's kernels can't consume —
fail fast with the fix spelled out rather than mid-epoch.

Every trained batch goes through ``ops.step(tables, step, cfg)``: the
replicated single-device jit, the Hogwild data-parallel path (sentences
shard over the ``data`` mesh axis, table replicas pmean-average), and the
vocab-sharded path (DESIGN.md §8: replicated Zipf-hot head, cold tail
striped over ``data``, request-exact cold-row exchange planned host-side
by ``distributed.vocab_placement``) are all dispatch outcomes of the
``Tables`` the session hands it. The window-tiled kernel family
(``cfg.tile_windows > 1``) composes with every path. Mixed-precision
storage (``cfg.tables`` — DESIGN.md §11) stores the hot head in bf16
and/or the cold tail in bf16/int8 with per-row scales; the session
attaches the per-batch rounding key so stochastic storage rounding stays
bit-deterministic across worker counts and chaos recoveries.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Iterator, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.w2v import W2VConfig
from repro.data.batching import Batch, BatchingPipeline
from repro.kernels import ops, quant, registry
from repro.kernels import tables as tables_mod
from repro.kernels.registry import StepInputs
from repro.kernels.tables import Tables, TableSpec

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainState:
    """Training state: embedding tables + progress counters.

    Replicated sessions hold the full ``(V, d)`` tables in ``w_in`` /
    ``w_out``. Vocab-sharded sessions (``cfg.vocab_shard``) hold the
    replicated hot head there instead, plus the striped cold tail in
    ``cold_in`` / ``cold_out`` (``(cold_pad, d)``, rows over the ``data``
    axis — DESIGN.md §8). Tables live in their *storage* dtypes
    (``TableSpec``): int8 cold tails carry per-row f32 scales in
    ``scale_in`` / ``scale_out``, row-sharded exactly like the cold rows.
    """
    w_in: jax.Array
    w_out: jax.Array
    words_seen: int = 0
    batches_seen: int = 0
    epoch: int = 0
    epoch_batch: int = 0   # batches completed within the current epoch
    cold_in: Optional[jax.Array] = None    # vocab-sharded cold tail
    cold_out: Optional[jax.Array] = None
    scale_in: Optional[jax.Array] = None   # int8 per-row scales (cold)
    scale_out: Optional[jax.Array] = None

    def params(self) -> Dict[str, jax.Array]:
        """Checkpointable table pytree (split names when vocab-sharded;
        int8 cold tails include their per-row scale leaves)."""
        if self.cold_in is not None:
            out = {"hot_in": self.w_in, "hot_out": self.w_out,
                   "cold_in": self.cold_in, "cold_out": self.cold_out}
            if self.scale_in is not None:
                out["scale_in"] = self.scale_in
                out["scale_out"] = self.scale_out
            return out
        return {"w_in": self.w_in, "w_out": self.w_out}


@dataclasses.dataclass
class StepMetrics:
    """Per-batch metrics yielded by :meth:`TrainSession.stream`.

    ``fetch_seconds`` is the time the step loop spent *blocked waiting* for
    this batch from the host pipeline — the overlap-efficiency signal: with
    prefetch on it should collapse toward zero while the device stays busy.
    ``queue_depth`` is the async pipeline's ready-batch depth when this
    batch was taken (-1 for synchronous pipelines). ``skipped`` marks a
    poison batch the supervisor excised (counters advanced, tables
    untouched — DESIGN.md §9).
    """
    epoch: int
    batches_seen: int
    words_seen: int
    batch_words: int
    lr: float
    backend: str
    fetch_seconds: float = 0.0
    queue_depth: int = -1
    skipped: bool = False


def init_state(vocab_size: int, cfg: W2VConfig, seed: int = 0,
               placement=None, mesh: Optional[Mesh] = None,
               spec: Optional[TableSpec] = None) -> TrainState:
    """Mikolov init: w_in ~ U(-0.5/d, 0.5/d), w_out = 0.

    With a ``placement`` (vocab sharding), the *same* full-table init is
    drawn and then split hot/cold — so a sharded session starts from
    exactly the tables a replicated one would (the parity baseline), and
    the cold tail is placed with rows over the ``data`` axis. Sub-f32
    storage dtypes in ``spec`` encode the init round-to-nearest (the
    deterministic seam — see ``kernels.quant``); ``w_out = 0`` is exact
    in every storage dtype, so quantized sessions start from the same
    zero output table.
    """
    spec = spec or TableSpec(vocab_shard=placement is not None)
    key = jax.random.PRNGKey(seed)
    d = cfg.dim
    w_in = (jax.random.uniform(key, (vocab_size, d), jnp.float32) - 0.5) / d
    w_out = jnp.zeros((vocab_size, d), jnp.float32)
    if placement is None:
        w_in, _ = quant.encode_nearest(w_in, spec.hot_dtype)
        w_out, _ = quant.encode_nearest(w_out, spec.hot_dtype)
        return TrainState(w_in=w_in, w_out=w_out)
    hot_in, cold_in = placement.split(np.asarray(w_in))
    hot_out, cold_out = placement.split(np.asarray(w_out))
    h_in, _ = quant.encode_nearest(jnp.asarray(hot_in), spec.hot_dtype)
    h_out, _ = quant.encode_nearest(jnp.asarray(hot_out), spec.hot_dtype)
    c_in, s_in = quant.encode_nearest(jnp.asarray(cold_in), spec.cold_dtype)
    c_out, s_out = quant.encode_nearest(jnp.asarray(cold_out),
                                        spec.cold_dtype)
    put = _cold_put(mesh, cold_in.shape[0])
    return TrainState(
        w_in=h_in, w_out=h_out, cold_in=put(c_in), cold_out=put(c_out),
        scale_in=None if s_in is None else put(s_in),
        scale_out=None if s_out is None else put(s_out))


def _cold_put(mesh: Optional[Mesh], cold_pad: int) -> Callable:
    """device_put for cold tables under the ``cold_vocab`` sharding rule."""
    if mesh is None:
        return jnp.asarray
    from repro.distributed.sharding import vocab_shard_sharding
    sharding = vocab_shard_sharding(mesh, cold_pad)
    return lambda arr: jax.device_put(jnp.asarray(arr), sharding)


class TrainSession:
    """A streaming W2V training session over a batching pipeline.

    Parameters
    ----------
    backend : registry name or ``"auto"``. Resolved once at construction
        (``cfg.tile_windows > 1`` selects the window-tiled family); bad
        names or invalid capability combinations raise immediately.
    mesh : optional device mesh with a ``data`` axis for Hogwild data
        parallelism. Composes with ``cfg.tile_windows > 1`` and with
        ``cfg.vocab_shard`` (which synthesizes a 1-device mesh when none
        is given, so the sharded code path always runs under shard_map).
    ckpt_dir / ckpt_every : when set, checkpoint every N batches (atomic,
        pruned) and — unless ``resume=False`` — restore the latest
        checkpoint at construction, continuing words/batches/epoch counts.
    on_batch / on_metrics : callbacks after every trained batch, receiving
        the :class:`TrainState` / :class:`StepMetrics` respectively.
    """

    def __init__(
        self,
        pipeline: BatchingPipeline,
        cfg: W2VConfig,
        backend: str = "auto",
        mesh: Optional[Mesh] = None,
        sync_every: int = 1,
        on_batch: Optional[Callable[[TrainState], None]] = None,
        on_metrics: Optional[Callable[[StepMetrics], None]] = None,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 0,
        resume: bool = True,
        exchange: Optional[str] = None,
    ):
        self.pipeline = pipeline
        self.cfg = cfg
        # the storage spec: cfg.tables when set (dtypes, hot fraction,
        # exchange flavor, sharding), else derived from the legacy
        # vocab_shard/hot_vocab_frac knobs. The explicit `exchange`
        # argument overrides the spec — "exact" (request-exact all_to_all
        # buckets, the default) or "dense" (the all_gather + psum_scatter
        # reference path the parity tests compare against)
        spec = tables_mod.from_config(cfg)
        if exchange is not None:
            spec = dataclasses.replace(spec, exchange=exchange)
        self.spec = spec
        self.exchange = spec.exchange
        # resolve once against the registry: invalid backend/capability
        # combinations (unknown name, TPU-only backend off-TPU, plan
        # mismatch, storage dtypes the kernels can't consume) fail here,
        # not mid-epoch. The *requested* name is kept for dispatch so
        # batches without a plan (T=1) can still resolve their sequential
        # variant
        self._requested_backend = backend
        self.backend = registry.resolve(
            backend, tiled=cfg.tile_windows > 1,
            vocab_shard=spec.vocab_shard,
            dtypes=() if spec.master_copy else spec.dtypes,
            frontends=getattr(pipeline, "frontend_features", ())).name
        if spec.vocab_shard and mesh is None:
            # the sharded step runs under shard_map even for one device, so
            # the 1-shard path exercises the exact N-shard code
            mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        self.mesh = mesh
        self.sync_every = sync_every
        self.on_batch = on_batch
        self.on_metrics = on_metrics
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.placement = None
        # the trainable table covers the vocabulary plus any frontend
        # extras (doc rows, n-gram buckets — DESIGN.md §12); extras carry
        # zero counts so placement planning stripes them into the cold tail
        table_rows = getattr(pipeline, "table_rows", pipeline.vocab.size)
        if spec.vocab_shard:
            from repro.distributed.vocab_placement import VocabPlacement
            counts = (pipeline.table_counts()
                      if hasattr(pipeline, "table_counts")
                      else pipeline.vocab.counts)
            self.placement = VocabPlacement.plan(
                counts, int(mesh.shape["data"]),
                hot_frac=spec.hot_frac)
            # hand the placement to the host pipeline so exchange plans are
            # computed in its finalize workers, off the step critical path
            # (Batch.exchange); _make_step falls back to inline planning
            # for pipelines (or batches) without one
            pipeline.placement = self.placement
        self.state = init_state(table_rows, cfg, cfg.seed,
                                placement=self.placement, mesh=mesh,
                                spec=spec)
        self.total_words = max(1, pipeline.epoch_words * cfg.epochs)
        self.words_per_sec = 0.0
        self.fetch_seconds = 0.0   # cumulative wait on the host pipeline
        self.wall_seconds = 0.0    # last train() wall time
        self.resumed_step: Optional[int] = None
        self._resume_skip = 0
        # poison-batch excision (DESIGN.md §9): stream positions the
        # supervisor decided to skip after a health rollback. Counters
        # still advance (LR schedule + pipeline cursor unchanged); only
        # the table update is excised. Skips are counted, never silent.
        self.poison_skip: Set[Tuple[int, int]] = set()
        self.batches_skipped = 0
        if ckpt_dir and resume:
            self._maybe_resume()
        if mesh is not None and not registry.get(self.backend).supports_mesh:
            raise ValueError(
                f"backend {self.backend!r} does not support mesh sharding")

    # -- learning-rate schedule (classic linear decay) ----------------------
    def _lr_at(self, words_seen: int) -> float:
        frac = 1.0 - words_seen / self.total_words
        return self.cfg.lr * max(frac, self.cfg.min_lr_frac)

    def current_lr(self) -> float:
        return self._lr_at(self.state.words_seen)

    def _tables(self) -> Tables:
        """The state's tables as the ``ops.step`` pytree (spec/placement
        ride as static metadata)."""
        st = self.state
        return Tables(w_in=st.w_in, w_out=st.w_out,
                      cold_in=st.cold_in, cold_out=st.cold_out,
                      scale_in=st.scale_in, scale_out=st.scale_out,
                      spec=self.spec, placement=self.placement)

    def _make_step(self, batch: Batch, lr) -> StepInputs:
        """Device StepInputs for a batch: the vocab-sharded exchange plan
        when the session shards the vocabulary, the plain lift otherwise.
        Batches from a placement-aware pipeline arrive with the exchange
        plan already computed in the finalize workers (``batch.exchange``);
        only placement-less batches pay for inline planning here. With
        sub-f32 storage the step also carries the batch's rounding key —
        a pure function of (seed, epoch, batch index), like the
        subsample/negative draws, so stochastic storage rounding replays
        bit-identically at any worker count."""
        if self.placement is not None:
            ex = getattr(batch, "exchange", None)
            if ex is None or ex.placement != self.placement:
                from repro.distributed.vocab_placement import plan_exchange
                ex = plan_exchange(batch, self.placement)
            step = ex.step_inputs(lr)
        else:
            step = batch.step_inputs(lr)
        if self.spec.is_mixed:
            key = quant.round_key(self.cfg.seed, batch.epoch, batch.index)
            step = dataclasses.replace(step, round_key=jnp.asarray(key))
        return step

    # -- train ---------------------------------------------------------------
    def train_batch(self, batch: Batch,
                    step: Optional[StepInputs] = None,
                    fetch_seconds: float = 0.0) -> StepMetrics:
        """Train one batch. ``step`` may be a pre-built (already
        device_put) :class:`StepInputs` from the prefetch path — its lr was
        computed from the projected word count, which equals
        ``current_lr()`` exactly because word counts are known host-side
        ahead of training."""
        lr = self.current_lr()
        skipped = ((self.state.epoch, self.state.epoch_batch)
                   in self.poison_skip)
        if skipped:
            self.batches_skipped += 1
            log.warning(
                "skipping poison batch (epoch %d, batch %d) — counters "
                "advance, tables untouched (%d skipped so far)",
                self.state.epoch, self.state.epoch_batch,
                self.batches_skipped)
        elif step is None:
            step = self._make_step(batch, lr)
        elif self.placement is not None and not step.has_vocab_shard:
            # a plain pre-built step carries un-remapped global ids; the
            # sharded path needs the exchange plan, so rebuild from the
            # host batch rather than crash (or silently corrupt) below
            step = self._make_step(batch, lr)
        if not skipped:
            out = ops.step(self._tables(), step, self.cfg,
                           backend=self._requested_backend, mesh=self.mesh)
            st = self.state
            st.w_in, st.w_out = out.w_in, out.w_out
            st.cold_in, st.cold_out = out.cold_in, out.cold_out
            st.scale_in, st.scale_out = out.scale_in, out.scale_out
        self.state.words_seen += batch.n_words
        self.state.batches_seen += 1
        self.state.epoch_batch += 1
        self.fetch_seconds += fetch_seconds
        metrics = StepMetrics(
            epoch=self.state.epoch, batches_seen=self.state.batches_seen,
            words_seen=self.state.words_seen, batch_words=batch.n_words,
            lr=lr, backend=self.backend, fetch_seconds=fetch_seconds,
            queue_depth=getattr(self.pipeline, "ready_depth", -1),
            skipped=skipped)
        if (self.ckpt_dir and self.ckpt_every
                and self.state.batches_seen % self.ckpt_every == 0):
            self.save_checkpoint()
        if self.on_batch is not None:
            self.on_batch(self.state)
        if self.on_metrics is not None:
            self.on_metrics(metrics)
        return metrics

    def _prepared(self, batch_iter: Iterator[Batch]
                  ) -> Iterator[tuple]:
        """Lift host batches onto the device one step ahead (double
        buffering): batch k+1's ``jax.device_put`` is issued while the
        device still computes batch k, so host→device transfer overlaps
        the update. lr for batch k+1 is exact, not estimated — it depends
        only on cumulative host-side word counts."""
        projected = self.state.words_seen
        try:
            for batch in batch_iter:
                lr = self._lr_at(projected)
                step = self._make_step(batch, lr)  # async transfer starts
                projected += batch.n_words
                yield batch, step
        finally:
            close = getattr(batch_iter, "close", None)
            if close is not None:
                close()

    def stream(self, epochs: Optional[int] = None,
               max_batches: Optional[int] = None) -> Iterator[StepMetrics]:
        """Stream the session: train batch by batch, yielding metrics after
        each. Resumed sessions continue from the checkpointed position —
        randomness is keyed by (epoch, batch index), so the pipeline's
        ``skip_batches`` fast-forward reproduces the exact remainder of the
        interrupted epoch without re-finalizing (or re-counting) anything.

        With ``cfg.prefetch_workers > 0`` the loop double-buffers: while
        the device updates batch k, the async pipeline finalizes batches
        k+1.. in its workers and batch k+1's device transfer is in flight.
        """
        epochs = epochs if epochs is not None else self.cfg.epochs
        pad_len = self.cfg.resolved_pad_len
        n_batches = 0
        skip = self._resume_skip  # >0 only right after a mid-epoch restore
        self._resume_skip = 0
        for ep in range(min(self.state.epoch, epochs), epochs):
            self.state.epoch = ep
            if not skip:
                self.state.epoch_batch = 0
            it = self.pipeline.batches(pad_len=pad_len, epoch=ep,
                                       skip_batches=skip)
            skip = 0
            prepared = self._prepared(it)
            try:
                t0 = time.perf_counter()
                cur = next(prepared, None)
                wait = time.perf_counter() - t0
                while cur is not None:
                    batch, step = cur
                    metrics = self.train_batch(batch, step=step,
                                               fetch_seconds=wait)
                    n_batches += 1
                    done = (max_batches is not None
                            and n_batches >= max_batches)
                    if done:
                        yield metrics
                        return
                    # with prefetch, pull batch k+1 *before* yielding: the
                    # update just dispatched is still running on the device
                    # while the host pipeline hands over (or finishes) k+1
                    t0 = time.perf_counter()
                    cur = next(prepared, None)
                    wait = time.perf_counter() - t0
                    yield metrics
            finally:
                prepared.close()

    def train(self, epochs: Optional[int] = None,
              max_batches: Optional[int] = None) -> TrainState:
        """Drain :meth:`stream` to completion; returns the final state."""
        words0 = self.state.words_seen
        self.fetch_seconds = 0.0
        t0 = time.perf_counter()
        for _ in self.stream(epochs=epochs, max_batches=max_batches):
            pass
        jax.block_until_ready(self.state.w_in)
        dt = time.perf_counter() - t0
        self.wall_seconds = dt
        self.words_per_sec = ((self.state.words_seen - words0) / dt
                              if dt else 0.0)
        return self.state

    def train_resilient(self, **kwargs) -> TrainState:
        """Drive :meth:`stream` under the recovery supervisor: restore +
        replay on step failure, health-probe rollback, watchdog timeouts,
        restart budget with refill (``repro.train.supervisor``, DESIGN.md
        §9). Keyword arguments go to :class:`TrainSupervisor`; the
        supervisor's :class:`SupervisorReport` lands on
        ``self.last_report``."""
        from repro.train.supervisor import TrainSupervisor
        sup = TrainSupervisor(self, **kwargs)
        words0 = self.state.words_seen
        self.fetch_seconds = 0.0
        t0 = time.perf_counter()
        state = sup.run()
        jax.block_until_ready(self.state.w_in)
        dt = time.perf_counter() - t0
        self.wall_seconds = dt
        self.words_per_sec = ((self.state.words_seen - words0) / dt
                              if dt else 0.0)
        self.last_report = sup.report
        return state

    @property
    def device_busy_frac(self) -> float:
        """Fraction of the last ``train()`` wall time NOT spent blocked on
        the host pipeline — the overlap-efficiency headline: ~host-bound
        when low, compute-bound (the paper's goal) when near 1."""
        if not self.wall_seconds:
            return 0.0
        return max(0.0, 1.0 - self.fetch_seconds / self.wall_seconds)

    # -- checkpoint / resume --------------------------------------------------
    def save_checkpoint(self) -> str:
        """Atomically checkpoint tables + progress counters + the host
        pipeline cursor (exact mid-epoch resume, prefetch or not)."""
        from repro.train import checkpoint as ckpt
        assert self.ckpt_dir, "TrainSession has no ckpt_dir"
        cursor = ckpt.PipelineCursor(
            epoch=self.state.epoch, epoch_batch=self.state.epoch_batch,
            prefetch_workers=self.cfg.prefetch_workers)
        extra = {"words_seen": self.state.words_seen,
                 "batches_seen": self.state.batches_seen,
                 "backend": self.backend, "tables": self.spec.to_extra(),
                 **cursor.to_extra()}
        if self.placement is not None:
            extra["vocab_shard"] = self.placement.to_extra()
        return ckpt.save(
            self.ckpt_dir, self.state.batches_seen, self.state.params(),
            extra=extra)

    def _restore_tables(self, step: int) -> Dict:
        """Restore embedding tables across table *formats*: split-table
        (vocab-sharded) vs replicated, and any storage-dtype mix — a
        mixed-precision checkpoint restores into an f32 session and vice
        versa. Cross-format restores decode the writing run's storage to
        the full f32 tables (through its placement and TableSpec, both
        recorded in the checkpoint extra) and re-encode round-to-nearest
        through this session's spec. Same-format restores (same leaf set,
        shapes, dtypes, and placement) skip the round trip and keep the
        exact storage bytes."""
        from repro.distributed.vocab_placement import VocabPlacement
        from repro.train import checkpoint as ckpt
        leaves, extra = ckpt.peek(self.ckpt_dir, step=step)
        split_ckpt = "hot_in" in leaves
        like_now = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in self.state.params().items()}
        same_format = (set(leaves) == set(like_now) and all(
            tuple(leaves[k]["shape"]) == tuple(like_now[k].shape)
            and leaves[k]["dtype"] == str(like_now[k].dtype)
            for k in like_now))
        if same_format and split_ckpt:
            # shapes alone can coincide across shard counts (equal
            # cold_pad, different stripe order) — the placements must
            # match exactly or the cold rows land on the wrong shards
            meta = extra.get("vocab_shard")
            same_format = (self.placement is not None and meta is not None
                           and VocabPlacement.from_extra(meta)
                           == self.placement)
        if same_format:
            tree, extra = ckpt.restore(self.ckpt_dir, like_now, step=step)
        else:
            like_ckpt = {
                k: jax.ShapeDtypeStruct(tuple(m["shape"]),
                                        ckpt.np_dtype(m["dtype"]))
                for k, m in leaves.items()}
            tree, extra = ckpt.restore(self.ckpt_dir, like_ckpt, step=step)
            src_spec = TableSpec.from_extra(extra.get("tables", {}))

            def dec_cold(name: str, sname: str) -> np.ndarray:
                cold = np.asarray(tree[name]).astype(np.float32)
                if src_spec.cold_dtype == "int8":
                    cold = cold * np.asarray(tree[sname])[:, None]
                return cold

            if split_ckpt:
                src = VocabPlacement.from_extra(extra["vocab_shard"])
                full_in = src.merge(
                    np.asarray(tree["hot_in"]).astype(np.float32),
                    dec_cold("cold_in", "scale_in"))
                full_out = src.merge(
                    np.asarray(tree["hot_out"]).astype(np.float32),
                    dec_cold("cold_out", "scale_out"))
            else:
                full_in = np.asarray(tree["w_in"]).astype(np.float32)
                full_out = np.asarray(tree["w_out"]).astype(np.float32)
            # restoring through like_ckpt skipped restore()'s shape check
            # against *this* session — validate before training reads rows
            # out of range (jax clamps gathers: silent corruption)
            v_expect = (self.placement.vocab_size
                        if self.placement is not None
                        else int(self.state.w_in.shape[0]))
            want = (v_expect, self.cfg.dim)
            if full_in.shape != want:
                raise ValueError(
                    f"checkpoint tables are {full_in.shape}, session "
                    f"expects {want} (vocabulary or dim mismatch — wrong "
                    f"ckpt_dir?)")
            if self.placement is not None:
                hot_in, cold_in = self.placement.split(full_in)
                hot_out, cold_out = self.placement.split(full_out)
                h_in, _ = quant.encode_nearest(jnp.asarray(hot_in),
                                               self.spec.hot_dtype)
                h_out, _ = quant.encode_nearest(jnp.asarray(hot_out),
                                                self.spec.hot_dtype)
                c_in, s_in = quant.encode_nearest(jnp.asarray(cold_in),
                                                  self.spec.cold_dtype)
                c_out, s_out = quant.encode_nearest(jnp.asarray(cold_out),
                                                    self.spec.cold_dtype)
                put = _cold_put(self.mesh, cold_in.shape[0])
                tree = {"hot_in": h_in, "hot_out": h_out,
                        "cold_in": put(c_in), "cold_out": put(c_out)}
                if s_in is not None:
                    tree["scale_in"] = put(s_in)
                    tree["scale_out"] = put(s_out)
            else:
                w_in, _ = quant.encode_nearest(jnp.asarray(full_in),
                                               self.spec.hot_dtype)
                w_out, _ = quant.encode_nearest(jnp.asarray(full_out),
                                                self.spec.hot_dtype)
                tree = {"w_in": w_in, "w_out": w_out}
        if self.placement is not None:
            self.state.w_in = tree["hot_in"]
            self.state.w_out = tree["hot_out"]
            self.state.cold_in = tree["cold_in"]
            self.state.cold_out = tree["cold_out"]
            self.state.scale_in = tree.get("scale_in")
            self.state.scale_out = tree.get("scale_out")
        else:
            self.state.w_in = tree["w_in"]
            self.state.w_out = tree["w_out"]
        return extra

    def restore_latest(self) -> Optional[int]:
        """Roll the session back to the newest *readable* checkpoint.
        Corrupt/partial step directories are quarantined by the checkpoint
        layer and skipped; with no usable checkpoint at all (or no
        ``ckpt_dir``) the session re-initializes from the seed — keyed
        randomness makes replay-from-scratch bit-exact too. Returns the
        restored step, or None when starting over. Sets the pipeline
        fast-forward so the next :meth:`stream` resumes mid-epoch exactly
        where the checkpoint left off."""
        from repro.train import checkpoint as ckpt
        while True:
            step = (ckpt.latest_step(self.ckpt_dir) if self.ckpt_dir
                    else None)
            if step is None:
                log.warning("no usable checkpoint — re-initializing from "
                            "seed %d", self.cfg.seed)
                self.state = init_state(
                    getattr(self.pipeline, "table_rows",
                            self.pipeline.vocab.size),
                    self.cfg, self.cfg.seed, placement=self.placement,
                    mesh=self.mesh, spec=self.spec)
                self._resume_skip = 0
                self.resumed_step = None
                return None
            try:
                extra = self._restore_tables(step)
            except ckpt.CorruptCheckpoint:
                # quarantined inside restore(); the next latest_step no
                # longer sees it — fall back to the one before
                continue
            self.state.words_seen = int(extra.get("words_seen", 0))
            self.state.batches_seen = int(extra.get("batches_seen", step))
            cursor = ckpt.PipelineCursor.from_extra(extra)
            self.state.epoch = cursor.epoch
            self.state.epoch_batch = cursor.epoch_batch
            self._resume_skip = cursor.epoch_batch
            self.resumed_step = step
            return step

    def _maybe_resume(self) -> None:
        from repro.train import checkpoint as ckpt
        if ckpt.latest_step(self.ckpt_dir) is None:
            return   # fresh start: keep the init-state tables as built
        self.restore_latest()

    # -- inference helpers ----------------------------------------------------
    def embeddings(self) -> np.ndarray:
        """The input embedding table ``(V, d)`` as f32 (quantized storage
        decodes once here); vocab-sharded sessions reassemble it from the
        hot replica + cold shards. NOTE: for a sharded session this
        gathers the full table onto one host — fine for examples and
        tests, wrong for serving; the serve path uses
        :meth:`embeddings_sharded` instead."""
        if self.placement is not None:
            hot = np.asarray(self.state.w_in).astype(np.float32)
            cold = np.asarray(quant.decode(self.state.cold_in,
                                           self.state.scale_in,
                                           self.spec.cold_dtype))
            return self.placement.merge(hot, cold)
        return np.asarray(self.state.w_in).astype(np.float32)

    def embeddings_sharded(self):
        """Shard-aware f32 view of the input table — no ``(V, d)`` gather.

        Returns ``(hot, cold, placement)``: for a vocab-sharded session,
        the replicated hot head ``(hot, d)``, the shard-major cold table
        ``(cold_pad, d)`` (still device-resident with its training
        sharding), and the :class:`VocabPlacement` describing the
        layout. For a replicated session, ``(w_in, None, None)`` — the
        caller chooses its own serving split
        (:meth:`repro.serve.index.EmbeddingIndex.from_session`).
        Quantized storage dequantizes here — once, at snapshot time —
        so serving reads plain f32 rows (elementwise decode preserves
        the cold table's device sharding)."""
        if self.placement is not None:
            cold = quant.decode(self.state.cold_in, self.state.scale_in,
                                self.spec.cold_dtype)
            return (self.state.w_in.astype(jnp.float32), cold,
                    self.placement)
        return self.state.w_in.astype(jnp.float32), None, None

    def nearest(self, word_id: int, k: int = 5) -> np.ndarray:
        e = self.embeddings()
        e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-12)
        sims = e @ e[word_id]
        sims[word_id] = -np.inf
        return np.argsort(-sims)[:k]


# Backwards-compatible name: the session IS the trainer.
W2VTrainer = TrainSession
