"""W2V trainer: epochs, linear LR decay, Hogwild data parallelism, recovery.

Single-device path runs the FULL-W2V kernel (or oracle) directly. The
multi-device path realizes the paper's "multiple GPUs on the same node"
future-work: sentences are sharded over the ``data`` mesh axis, each device
runs the sequential FULL-W2V pass on its shard against a local table replica
(Hogwild — benign divergence), and replicas are averaged every
``sync_every`` batches (optionally int8-compressed cross-pod, see
``distributed.compression``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.w2v import W2VConfig
from repro.data.batching import Batch, BatchingPipeline
from repro.kernels import ops


@dataclasses.dataclass
class TrainState:
    w_in: jax.Array
    w_out: jax.Array
    words_seen: int = 0
    batches_seen: int = 0
    epoch: int = 0

    def params(self) -> Dict[str, jax.Array]:
        return {"w_in": self.w_in, "w_out": self.w_out}


def init_state(vocab_size: int, cfg: W2VConfig, seed: int = 0) -> TrainState:
    """Mikolov init: w_in ~ U(-0.5/d, 0.5/d), w_out = 0."""
    key = jax.random.PRNGKey(seed)
    d = cfg.dim
    w_in = (jax.random.uniform(key, (vocab_size, d), jnp.float32) - 0.5) / d
    w_out = jnp.zeros((vocab_size, d), jnp.float32)
    return TrainState(w_in=w_in, w_out=w_out)


class W2VTrainer:
    def __init__(
        self,
        pipeline: BatchingPipeline,
        cfg: W2VConfig,
        backend: str = "auto",
        mesh: Optional[Mesh] = None,
        sync_every: int = 1,
        on_batch: Optional[Callable[[TrainState], None]] = None,
    ):
        self.pipeline = pipeline
        self.cfg = cfg
        self.backend = backend
        self.mesh = mesh
        self.sync_every = sync_every
        self.on_batch = on_batch
        self.state = init_state(pipeline.vocab.size, cfg, cfg.seed)
        self.total_words = max(1, pipeline.epoch_words * cfg.epochs)
        self.words_per_sec = 0.0
        if mesh is not None:
            if cfg.tile_windows > 1:
                # the sharded update path has no tiled dispatch yet; running
                # it would silently train tile-shared negatives on the
                # sequential kernel — refuse instead of mis-training
                raise NotImplementedError(
                    "tile_windows > 1 is not supported with a device mesh "
                    "yet; use the single-device path or tile_windows=1")
            self._dp_update = self._build_dp_update(mesh)

    # -- learning-rate schedule (classic linear decay) ----------------------
    def current_lr(self) -> float:
        frac = 1.0 - self.state.words_seen / self.total_words
        return self.cfg.lr * max(frac, self.cfg.min_lr_frac)

    # -- data-parallel Hogwild step ------------------------------------------
    def _build_dp_update(self, mesh: Mesh):
        from jax.experimental.shard_map import shard_map

        w_f = self.cfg.fixed_window
        backend = self.backend

        def local_update(w_in, w_out, toks, negs, lens, lr):
            new_in, new_out = ops.sgns_batch_update(
                w_in, w_out, toks, negs, lens, lr, w_f, backend=backend)
            # Hogwild model averaging across the data axis
            new_in = jax.lax.pmean(new_in, "data")
            new_out = jax.lax.pmean(new_out, "data")
            return new_in, new_out

        fn = shard_map(
            local_update, mesh=mesh,
            in_specs=(P(), P(), P("data"), P("data"), P("data"), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )
        return jax.jit(fn, donate_argnums=(0, 1))

    # -- train ---------------------------------------------------------------
    def train_batch(self, batch: Batch) -> None:
        lr = jnp.float32(self.current_lr())
        toks = jnp.asarray(batch.tokens)
        negs = jnp.asarray(batch.negs)
        lens = jnp.asarray(batch.lengths)
        if self.mesh is not None:
            self.state.w_in, self.state.w_out = self._dp_update(
                self.state.w_in, self.state.w_out, toks, negs, lens, lr)
        elif batch.plan is not None and batch.plan.tile > 1:
            # window-tile batched path (cfg.tile_windows > 1, DESIGN.md §4)
            p = batch.plan
            self.state.w_in, self.state.w_out = ops.sgns_batch_update_tiled(
                self.state.w_in, self.state.w_out, toks, negs, lens, lr,
                self.cfg.fixed_window, p.tile,
                jnp.asarray(p.uniq), jnp.asarray(p.scatter),
                jnp.asarray(p.ucount), jnp.asarray(p.strict),
                backend=ops.tiled_backend(self.backend),
                gemm_windows=self.cfg.tile_gemm_windows)
        else:
            self.state.w_in, self.state.w_out = ops.sgns_batch_update(
                self.state.w_in, self.state.w_out, toks, negs, lens, lr,
                self.cfg.fixed_window, backend=self.backend)
        self.state.words_seen += batch.n_words
        self.state.batches_seen += 1
        if self.on_batch is not None:
            self.on_batch(self.state)

    def train(self, epochs: Optional[int] = None,
              max_batches: Optional[int] = None) -> TrainState:
        epochs = epochs if epochs is not None else self.cfg.epochs
        pad_len = min(self.cfg.max_sentence_len, 1024)
        n_batches = 0
        t0 = time.perf_counter()
        for ep in range(epochs):
            self.state.epoch = ep
            for batch in self.pipeline.batches(pad_len=pad_len):
                self.train_batch(batch)
                n_batches += 1
                if max_batches is not None and n_batches >= max_batches:
                    break
            if max_batches is not None and n_batches >= max_batches:
                break
        jax.block_until_ready(self.state.w_in)
        dt = time.perf_counter() - t0
        self.words_per_sec = self.state.words_seen / dt if dt else 0.0
        return self.state

    # -- inference helpers ----------------------------------------------------
    def embeddings(self) -> np.ndarray:
        return np.asarray(self.state.w_in)

    def nearest(self, word_id: int, k: int = 5) -> np.ndarray:
        e = self.embeddings()
        e = e / np.maximum(np.linalg.norm(e, axis=1, keepdims=True), 1e-12)
        sims = e @ e[word_id]
        sims[word_id] = -np.inf
        return np.argsort(-sims)[:k]
