"""Ring-buffer lifetime bookkeeping for context words (paper §3.2).

A word at position ``p`` of a sentence is a *context* word of the windows
centred at ``p - W_f .. p + W_f`` (except its own window ``p``). FULL-W2V
keeps its input-embedding row resident in fast memory (GPU shared memory;
here a VMEM scratch buffer) for exactly that lifetime: loaded when window
``p - W_f`` begins (i.e. when it becomes the leading edge of the sliding
window), written back when window ``p + W_f`` has been processed.

The buffer needs ``R = 2*W_f + 1`` row slots; position ``p`` lives in slot
``p % R``. Slot reuse is conflict-free because positions ``p`` and ``p + R``
have disjoint lifetimes: ``p`` is dead after window ``p + W_f``, and ``p+R``
is first needed for window ``p + W_f + 1``.

This module is the *pure-python reference state machine*; `kernels/fullw2v.py`
and `kernels/ref.py` implement the same schedule in Pallas / jnp, and the
property tests check all three agree.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


def ring_slots(w_f: int) -> int:
    return 2 * w_f + 1


def slot_of(p: int, w_f: int) -> int:
    return p % ring_slots(w_f)


def lifetime(p: int, w_f: int, length: int) -> Tuple[int, int]:
    """Windows [first, last] (inclusive) during which position p must be
    buffer-resident. Clipped to the sentence."""
    return max(0, p - w_f), min(length - 1, p + w_f)


@dataclasses.dataclass
class Event:
    kind: str        # "load" | "store" | "window"
    window: int      # window index t at which the event happens
    position: int    # sentence position (load/store) or t (window)


def schedule(length: int, w_f: int) -> List[Event]:
    """The exact load/store/window event stream for one sentence.

    Before window t, position q = t + w_f is loaded (evicting q - R if it
    exists). Windows 0's preload covers positions 0..w_f-1. After the last
    window, surviving positions are flushed in increasing order.
    """
    r = ring_slots(w_f)
    ev: List[Event] = []
    for q in range(0, min(w_f, length)):
        ev.append(Event("load", 0, q))
    for t in range(length):
        q = t + w_f
        if q < length:
            old = q - r
            if old >= 0:
                ev.append(Event("store", t, old))
            ev.append(Event("load", t, q))
        ev.append(Event("window", t, t))
    # Flush: position p was evicted in-loop iff p + r was loaded, i.e.
    # p <= length - r - 1. Survivors are exactly p in [length - r, length).
    for p in range(max(0, length - r), length):
        ev.append(Event("store", length - 1, p))
    return ev


def loads_and_stores(length: int, w_f: int) -> Tuple[int, int]:
    evs = schedule(length, w_f)
    return (sum(1 for e in evs if e.kind == "load"),
            sum(1 for e in evs if e.kind == "store"))


def traffic_reduction(w_f: int) -> float:
    """Paper §3.2: lifetime reuse removes 2W_f/(2W_f+1) of context-row
    global-memory traffic (each row read+written once instead of once per
    window it participates in)."""
    return (2 * w_f) / (2 * w_f + 1)


class RingBufferSim:
    """Tiny simulator used by hypothesis tests: tracks which position each
    slot holds at each window and validates the invariant that every context
    position of window t is resident."""

    def __init__(self, length: int, w_f: int):
        self.length = length
        self.w_f = w_f
        self.r = ring_slots(w_f)
        self.slots: Dict[int, Optional[int]] = {i: None for i in range(self.r)}
        self.stored: List[int] = []
        self.loaded: List[int] = []

    def run(self) -> "RingBufferSim":
        for e in schedule(self.length, self.w_f):
            if e.kind == "load":
                s = slot_of(e.position, self.w_f)
                self.slots[s] = e.position
                self.loaded.append(e.position)
            elif e.kind == "store":
                s = slot_of(e.position, self.w_f)
                assert self.slots[s] == e.position, (
                    f"store of {e.position} but slot holds {self.slots[s]}")
                self.stored.append(e.position)
            else:
                t = e.window
                for p in range(max(0, t - self.w_f),
                               min(self.length, t + self.w_f + 1)):
                    s = slot_of(p, self.w_f)
                    assert self.slots[s] == p, (
                        f"window {t}: position {p} not resident "
                        f"(slot {s} holds {self.slots[s]})")
        return self
