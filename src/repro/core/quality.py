"""Embedding quality metrics — the offline analogue of paper Table 7.

WS-353/SimLex/analogy sets are external data; on the planted-cluster
synthetic corpus (`data.corpus.synthetic_cluster_corpus`) the ground-truth
similarity structure is known exactly, so we measure:

* `spearman_vs_truth` — Spearman rank correlation between embedding cosine
  similarity and ground-truth (same-cluster) similarity over sampled pairs —
  the WS-353/SimLex analogue;
* `cluster_separation` — mean intra-cluster minus mean inter-cluster cosine;
* `nn_purity` — fraction of words whose nearest neighbour shares the cluster
  (the analogy-reconstruction analogue).

The paper's claim being reproduced: FULL-W2V's reuse scheme gives quality
statistically equal to pWord2Vec/Wombat — i.e. all implementations here
must score the same within noise.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average-tie ranks (scipy.stats.rankdata('average') equivalent)."""
    order = np.argsort(x, kind="mergesort")
    ranks = np.empty(len(x), float)
    sx = x[order]
    i = 0
    while i < len(x):
        j = i
        while j + 1 < len(x) and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra, rb = _rankdata(a), _rankdata(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0


def _normalize(emb: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(emb, axis=1, keepdims=True)
    return emb / np.maximum(n, 1e-12)


def evaluate(emb: np.ndarray, clusters: np.ndarray,
             n_pairs: int = 20_000, seed: int = 0) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    v = emb.shape[0]
    e = _normalize(np.asarray(emb, np.float64))

    i = rng.integers(0, v, n_pairs)
    j = rng.integers(0, v, n_pairs)
    keep = i != j
    i, j = i[keep], j[keep]
    cos = (e[i] * e[j]).sum(1)
    truth = (clusters[i] == clusters[j]).astype(float)

    intra = cos[truth == 1.0]
    inter = cos[truth == 0.0]
    sep = float(intra.mean() - inter.mean()) if len(intra) and len(inter) else 0.0

    # nearest-neighbour purity on a sample of words
    sample = rng.choice(v, size=min(v, 512), replace=False)
    sims = e[sample] @ e.T
    sims[np.arange(len(sample)), sample] = -np.inf
    nn = sims.argmax(1)
    purity = float((clusters[sample] == clusters[nn]).mean())

    return {
        "spearman": spearman(cos, truth),
        "separation": sep,
        "nn_purity": purity,
    }
