"""Baseline SGNS implementations the paper compares against.

* `naive_sgns`  — accSGNS/Mikolov-style: one (context, target) pair at a
  time, immediate read-modify-write of every row against the table; no
  sharing, no lifetime reuse. Highest memory traffic (paper Table 4,
  accSGNS row).
* `matrix_sgns` — pWord2Vec-style: shared negatives per window as two small
  GEMMs, but context rows are re-read from / re-written to the table every
  window (no cross-window ring buffer). Traffic ≈ (2W_f+1)× FULL-W2V's for
  context rows (paper §3.2).

Both are faithful *semantics* baselines: on sentences without short-range
token repeats, `matrix_sgns` is mathematically identical to the FULL-W2V
ring-buffer pass (property-tested), differing only in memory traffic — which
is exactly the paper's claim.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sgns import pair_delta, window_delta


def _window_out_idx(tokens, negs, t):
    return jnp.concatenate([tokens[t][None], negs[t]])


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def matrix_sgns_sentence(
    w_in: jax.Array, w_out: jax.Array,
    tokens: jax.Array, negs: jax.Array, length: jax.Array,
    lr: jax.Array, w_f: int,
) -> Tuple[jax.Array, jax.Array]:
    """pWord2Vec-style shared-negative window updates, straight to the table."""
    L, N = negs.shape
    offsets = jnp.array([o for o in range(-w_f, w_f + 1) if o != 0],
                        jnp.int32)

    def step(t, carry):
        w_in, w_out = carry
        active = t < length
        p = t + offsets
        mask = active & (p >= 0) & (p < length)
        p_c = jnp.clip(p, 0, L - 1)
        ctx_idx = tokens[p_c]
        ctx = w_in[ctx_idx]                                    # table read/window
        out_idx = _window_out_idx(tokens, negs, t)
        out_rows = w_out[out_idx]
        d_ctx, d_out = window_delta(ctx, out_rows, mask, lr)
        w_in = w_in.at[ctx_idx].add(d_ctx)                     # table write/window
        w_out = w_out.at[out_idx].add(jnp.where(active, d_out, 0.0))
        return (w_in, w_out)

    return jax.lax.fori_loop(0, L, step, (w_in, w_out))


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def matrix_sgns(w_in, w_out, tokens, negs, lengths, lr, w_f: int):
    def body(carry, xs):
        toks, ngs, ln = xs
        return matrix_sgns_sentence(*carry, toks, ngs, ln, lr, w_f), None

    (w_in, w_out), _ = jax.lax.scan(body, (w_in, w_out),
                                    (tokens, negs, lengths))
    return w_in, w_out


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def naive_sgns_sentence(
    w_in: jax.Array, w_out: jax.Array,
    tokens: jax.Array, negs: jax.Array, length: jax.Array,
    lr: jax.Array, w_f: int,
) -> Tuple[jax.Array, jax.Array]:
    """accSGNS-style: sequential per-pair updates, every pairing its own
    table read-modify-write (the same window negatives are reused per pair,
    mirroring the shared-negative batching all modern impls use)."""
    L, N = negs.shape

    def pair_step(j, carry):
        # j enumerates (offset, out_row) pairs: j = off_idx * (N+1) + o_idx
        w_in, w_out, t = carry
        n_out = N + 1
        off_idx = j // n_out
        o_idx = j % n_out
        off = jnp.where(off_idx < w_f, off_idx - w_f, off_idx - w_f + 1)
        p = t + off
        valid = (t < length) & (p >= 0) & (p < length)
        p_c = jnp.clip(p, 0, L - 1)
        c_idx = tokens[p_c]
        out_idx = jnp.where(o_idx == 0, tokens[t], negs[t, jnp.maximum(o_idx - 1, 0)])
        label = (o_idx == 0).astype(w_in.dtype)
        d_in, d_out = pair_delta(w_in[c_idx], w_out[out_idx], label, lr)
        scale = jnp.where(valid, 1.0, 0.0)
        w_in = w_in.at[c_idx].add(scale * d_in)
        w_out = w_out.at[out_idx].add(scale * d_out)
        return (w_in, w_out, t)

    def step(t, carry):
        w_in, w_out = carry
        w_in, w_out, _ = jax.lax.fori_loop(
            0, 2 * w_f * (N + 1), pair_step, (w_in, w_out, t))
        return (w_in, w_out)

    return jax.lax.fori_loop(0, L, step, (w_in, w_out))


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def naive_sgns(w_in, w_out, tokens, negs, lengths, lr, w_f: int):
    def body(carry, xs):
        toks, ngs, ln = xs
        return naive_sgns_sentence(*carry, toks, ngs, ln, lr, w_f), None

    (w_in, w_out), _ = jax.lax.scan(body, (w_in, w_out),
                                    (tokens, negs, lengths))
    return w_in, w_out
