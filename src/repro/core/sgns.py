"""Canonical SGNS (skip-gram negative sampling) window math.

This module defines the *semantics* that every implementation in this repo
(pure-jnp oracle, Pallas kernel, baselines, distributed trainer) must agree
on. FULL-W2V (paper §3.1) exploits that within one context window every
(context-word × output-row) pairing commutes; following pWord2Vec (shared
negatives, which the paper adopts) we therefore compute every pairing from
the *pre-window* values and apply the accumulated deltas at window end. That
makes the window update exactly two small GEMMs — the TPU-native expression
of the paper's register/shared-memory pairing loop (DESIGN.md §2).

Window update, given
  C_in  : (K, d)    context-word input rows (K = 2·W_f, masked at edges)
  M_out : (N+1, d)  output rows: [target, negative_1 .. negative_N]
  label : (N+1,)    [1, 0, ..., 0]
is
  corr  = C_in @ M_out^T                  (K, N+1)
  g     = lr * (label - sigmoid(corr))    (K, N+1), zeroed where ctx invalid
  dC_in = g @ M_out                       (K, d)
  dM_out= g^T @ C_in                      (N+1, d)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def stable_sigmoid(x: jax.Array) -> jax.Array:
    """Numerically stable logistic; matches jax.nn.sigmoid but spelled out so
    the Pallas kernel can use the identical formula."""
    return jnp.where(
        x >= 0,
        1.0 / (1.0 + jnp.exp(-x)),
        jnp.exp(x) / (1.0 + jnp.exp(x)),
    )


def window_delta(
    ctx: jax.Array,        # (K, d) f32 — pre-window context input rows
    out_rows: jax.Array,   # (N+1, d) f32 — pre-window output rows
    ctx_mask: jax.Array,   # (K,) bool — which context slots are real words
    lr: jax.Array,         # scalar
) -> Tuple[jax.Array, jax.Array]:
    """Return (d_ctx (K,d), d_out (N+1,d)) for one shared-negative window.

    label vector is implicit: out_rows[0] is the positive target, the rest
    are negatives.
    """
    n_out = out_rows.shape[0]
    label = jnp.zeros((n_out,), ctx.dtype).at[0].set(1.0)
    corr = ctx @ out_rows.T                                   # (K, N+1)
    g = lr * (label[None, :] - stable_sigmoid(corr))          # (K, N+1)
    g = jnp.where(ctx_mask[:, None], g, 0.0)
    d_ctx = g @ out_rows                                      # (K, d)
    d_out = g.T @ ctx                                         # (N+1, d)
    return d_ctx, d_out


def window_context_positions(t: int, w_f: int, length: int) -> list:
    """Python helper (tests): context positions of window t."""
    return [p for p in range(t - w_f, t + w_f + 1)
            if p != t and 0 <= p < length]


def pair_delta(
    in_vec: jax.Array,   # (d,)
    out_vec: jax.Array,  # (d,)
    label: jax.Array,    # scalar 0/1
    lr: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single (input, output) pairing — building block of the naive
    (accSGNS-style) baseline."""
    f = stable_sigmoid(in_vec @ out_vec)
    g = lr * (label - f)
    return g * out_vec, g * in_vec
