"""The paper's primary contribution: FULL-W2V SGNS with lifetime data reuse.

sgns.py      — canonical shared-negative window math (all impls agree on it)
window.py    — ring-buffer lifetime state machine (reference + analysis)
baselines.py — accSGNS-like / pWord2Vec-like comparison implementations
trainer.py   — streaming TrainSession: LR decay, Hogwild mesh averaging,
               checkpoint/resume, metrics callbacks
quality.py   — planted-cluster embedding quality metrics (Table-7 analogue)
"""
from repro.core.sgns import pair_delta, stable_sigmoid, window_delta
from repro.core.trainer import (StepMetrics, TrainSession, TrainState,
                                W2VTrainer, init_state)

__all__ = ["pair_delta", "stable_sigmoid", "window_delta", "StepMetrics",
           "TrainSession", "TrainState", "W2VTrainer", "init_state"]
