"""Public entry point for the FULL-W2V kernel family (engine API).

One function — :func:`sgns_update` — replaces the old pair of jit'd
dispatchers (``sgns_batch_update`` / ``sgns_batch_update_tiled``) and the
hand-maintained sequential→tiled name map. Backend selection is data
driven: every kernel variant registers a capability descriptor in
``repro.kernels.registry`` and an ``update(w_in, w_out, step, static)``
implementation; resolution ("auto", tiled mapping, invalid combinations)
happens once against those descriptors.

Registered backends:

* ``jnp`` / ``jnp_tiled`` — the pure-jnp oracles (``kernels.ref``). Fully
  compiled, so also the fastest option on CPU.
* ``pallas`` / ``pallas_pipelined`` — the sequential Pallas kernel
  (``kernels.fullw2v``), the pipelined form adding §3.1 prefetch (window
  t+1's rows DMA while window t computes). TPU-native only.
* ``pallas_tiled`` — the window-tiled Pallas kernel (T windows fused per
  step, DESIGN.md §4). Consumes the host tile schedule carried in
  ``StepInputs.plan_*``. TPU-native only.
* ``pallas_interpret`` / ``pallas_tiled_interpret`` — the same kernels
  under ``interpret=True``: the kernel body executes in Python — identical
  semantics, correctness-only speed. What CI runs in this container.

Besides :func:`sgns_update` (single replica) this module provides
:func:`vocab_sharded_update` — the same backends run unchanged on the
compact working table of a vocab-sharded step (DESIGN.md §8), wrapped in
the gather / write-back exchange that keeps per-step traffic proportional
to distinct rows, not vocabulary size.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.w2v import W2VConfig, resolve_gemm_windows
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels.fullw2v import (fullw2v_pallas, fullw2v_pallas_tiled,
                                   fullw2v_pallas_tiled_fused)
from repro.kernels.registry import (KernelBackend, KernelStatic, StepInputs,
                                    register)


# ---------------------------------------------------------------------------
# Backend update() implementations (traceable; jit applied by the engine)
# ---------------------------------------------------------------------------

def _seq_args(step: StepInputs):
    return (step.tokens, step.negs, step.lengths,
            jnp.asarray(step.lr, jnp.float32))


def _tiled_args(step: StepInputs, static: KernelStatic):
    assert step.has_plan, "tiled backend requires StepInputs.plan_*"
    return (*_seq_args(step), static.w_f, static.tile, step.plan_uniq,
            step.plan_scatter, step.plan_ucount, step.plan_strict)


def _update_jnp(w_in, w_out, step, static):
    return _ref.batch_sgns_ref(w_in, w_out, *_seq_args(step), static.w_f)


def _update_pallas(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f)


def _update_pallas_pipelined(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          pipeline=True)


def _update_pallas_interpret(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          interpret=True)


def _update_jnp_tiled(w_in, w_out, step, static):
    return _ref.batch_sgns_tiled_ref(w_in, w_out,
                                     *_tiled_args(step, static),
                                     gemm_windows=static.gemm_windows)


def _update_pallas_tiled(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows)


def _update_pallas_tiled_interpret(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows,
                                interpret=True)


def _update_fused_pallas_tiled(hot_in, hot_out, got_in, got_out, step, static):
    return fullw2v_pallas_tiled_fused(hot_in, hot_out, got_in, got_out,
                                      *_tiled_args(step, static),
                                      gemm_windows=static.gemm_windows)


def _update_fused_pallas_tiled_interpret(hot_in, hot_out, got_in, got_out,
                                         step, static):
    return fullw2v_pallas_tiled_fused(hot_in, hot_out, got_in, got_out,
                                      *_tiled_args(step, static),
                                      gemm_windows=static.gemm_windows,
                                      interpret=True)


register(KernelBackend(
    name="jnp", update=_update_jnp,
    description="compiled jnp oracle (kernels.ref.batch_sgns_ref)",
    supports_tiling=True, supports_vocab_shard=True,
    tiled_variant="jnp_tiled"))
register(KernelBackend(
    name="pallas", update=_update_pallas,
    description="sequential Pallas kernel (TPU-native)",
    requires_tpu=True, supports_tiling=True, supports_vocab_shard=True,
    tiled_variant="pallas_tiled", interpret_variant="pallas_interpret"))
# pallas_pipelined opts OUT of vocab sharding: its §3.1 prefetch exists to
# hide HBM row latency, but a vocab-sharded step hands the kernel a compact
# VMEM-sized working table — prefetch buys nothing there, so the capable
# variant is plain `pallas` (and "auto" resolves to it).
register(KernelBackend(
    name="pallas_pipelined", update=_update_pallas_pipelined,
    description="sequential Pallas kernel with §3.1 prefetch (TPU-native)",
    requires_tpu=True, supports_pipeline=True, supports_tiling=True,
    tiled_variant="pallas_tiled", interpret_variant="pallas_interpret"))
register(KernelBackend(
    name="pallas_interpret", update=_update_pallas_interpret,
    description="sequential Pallas kernel, interpret mode (any platform)",
    supports_tiling=True, supports_vocab_shard=True,
    tiled_variant="pallas_tiled_interpret"))
register(KernelBackend(
    name="jnp_tiled", update=_update_jnp_tiled,
    description="window-tiled jnp oracle (kernels.ref.batch_sgns_tiled_ref)",
    needs_plan=True, supports_vocab_shard=True))
register(KernelBackend(
    name="pallas_tiled", update=_update_pallas_tiled,
    description="window-tiled Pallas kernel (TPU-native, DESIGN.md §4)",
    needs_plan=True, requires_tpu=True, supports_vocab_shard=True,
    interpret_variant="pallas_tiled_interpret",
    update_fused=_update_fused_pallas_tiled))
register(KernelBackend(
    name="pallas_tiled_interpret", update=_update_pallas_tiled_interpret,
    description="window-tiled Pallas kernel, interpret mode (any platform)",
    needs_plan=True, supports_vocab_shard=True,
    update_fused=_update_fused_pallas_tiled_interpret))


# ---------------------------------------------------------------------------
# The single dispatch entry point
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_update(name: str, static: KernelStatic):
    return jax.jit(traceable_update(name, static), donate_argnums=(0, 1))


def static_for(cfg: W2VConfig, tile: int = 1) -> KernelStatic:
    """The static kernel parameters for this config at tile size T."""
    return KernelStatic(
        w_f=cfg.fixed_window, tile=tile,
        gemm_windows=(resolve_gemm_windows(tile, cfg.tile_gemm_windows)
                      if tile > 1 else 0))


def traceable_update(backend: str, static: KernelStatic):
    """The resolved backend's raw traceable ``(w_in, w_out, step) ->
    (w_in, w_out)`` update — for callers that embed it in their own jit or
    shard_map (the trainer's Hogwild data-parallel step)."""
    be = registry.get(backend)

    def run(w_in: jax.Array, w_out: jax.Array, step: StepInputs):
        return be.update(w_in, w_out, step, static)

    return run


def sgns_update(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    step: StepInputs,     # tokens/negs/lengths/lr (+ tile plan if T > 1)
    cfg: W2VConfig,
    backend: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Train one batch of sentences with FULL-W2V semantics.

    The backend name resolves against the registry for this step's shape:
    ``step.has_plan`` selects the window-tiled kernel family (T windows
    fused per step, DESIGN.md §4; bit-identical to sequential at T=1), a
    plain step the sequential family. Tile size and GEMM grouping are
    static, derived from the plan shape and ``cfg.tile_gemm_windows``.

    Steps carrying a vocab-sharding exchange plan (``step.cold_ids``) are
    rejected here: their index arrays are remapped into per-shard working-
    table space and only mean anything under a mesh session
    (``TrainSession(mesh=..., cfg.vocab_shard=True)`` →
    :func:`vocab_sharded_update` under ``shard_map``).
    """
    if step.has_vocab_shard:
        raise ValueError(
            "StepInputs carries a vocab-sharding exchange plan (cold_ids); "
            "sgns_update is the single-replica entry point. Run the step "
            "through a mesh TrainSession with cfg.vocab_shard=True, or "
            "build the step without plan_exchange.")
    be = registry.resolve(backend, tiled=step.has_plan)
    return _jitted_update(be.name, static_for(cfg, step.tile))(
        w_in, w_out, step)


# ---------------------------------------------------------------------------
# Vocab-sharded update (DESIGN.md §8): hot replica + cold shard exchange
# ---------------------------------------------------------------------------

def vocab_sharded_update(backend: str, static: KernelStatic, placement,
                         axis_name: str = "data", exchange: str = "exact"):
    """The per-shard update for vocab-sharded tables, to run under
    ``shard_map`` over ``axis_name``.

    Signature of the returned function (all arguments are the *local*
    blocks shard_map hands each device):

        run(hot_in, hot_out, cold_in, cold_out, step)
            -> (hot_in', hot_out', cold_in', cold_out')

    where ``hot_*`` are the replicated ``(hot, d)`` head tables, ``cold_*``
    the local ``(cold_per_shard, d)`` shard of the striped cold tail, and
    ``step`` a :class:`~repro.kernels.registry.StepInputs` built by
    ``distributed.vocab_placement.plan_exchange`` (token/negative/plan ids
    remapped to working-table space, ``cold_ids`` = per-shard request
    lists, ``bucket_ids``/``bucket_pos`` = the per-owner capacity buckets).

    One step does, entirely on-device (DESIGN.md §8 exchange math):

    1. **Gather** (``exchange="exact"``, the default) — ``all_to_all`` the
       per-owner request buckets (ints, O(n·C) ≈ O(R)), serve the rows
       this shard owns, ``all_to_all`` the values back, and scatter them
       into request order via the host-planned bucket positions: every
       shard sends and receives O(R·d) bytes — request-exact, independent
       of both V and the mesh size. ``exchange="dense"`` keeps the PR 5
       all_gather + ``psum_scatter`` path (O(n·R·d) per device) as the
       parity reference.
    2. **Compute** — run the resolved backend on the compact working table
       of ``hot + R`` rows: backends declaring ``supports_fused_gather``
       are handed the hot replica and the gathered block as *separate*
       buffers (the kernel streams rows from whichever side owns them, no
       ``concat`` materialization); the rest run unchanged on
       ``concat(hot, gathered)``.
    3. **Write back** — pmean the hot head across shards (Hogwild
       averaging, identical to the replicated path); route the updated
       request rows back to their owners (``all_to_all`` over the same
       buckets, or all_gather on the dense path) and scatter-add them,
       averaging each touched row over all ``n`` replicas' contributions
       (untouched replicas contribute the pre-step value, which the owner
       reconstructs locally — see DESIGN.md §8 for the tolerance this
       implies vs. the replicated path).
    """
    be = registry.get(backend)
    if not be.supports_vocab_shard:
        raise ValueError(
            f"backend {backend!r} does not support vocab-sharded tables; "
            f"resolve with vocab_shard=True to get an actionable choice")
    if exchange not in ("exact", "dense"):
        raise ValueError(f"exchange must be 'exact' or 'dense', "
                         f"got {exchange!r}")
    hot = placement.hot
    cps = placement.cold_per_shard
    n = placement.n_shards

    def compute(hot_in, hot_out, got_in, got_out, step):
        """Run the backend on the working table; return (new_hot_in,
        new_hot_out, new_got_in, new_got_out)."""
        if be.supports_fused_gather:
            return be.update_fused(hot_in, hot_out, got_in, got_out,
                                   step, static)
        w_in_work = jnp.concatenate([hot_in, got_in], axis=0)
        w_out_work = jnp.concatenate([hot_out, got_out], axis=0)
        new_in, new_out = be.update(w_in_work, w_out_work, step, static)
        return new_in[:hot], new_out[:hot], new_in[hot:], new_out[hot:]

    def hogwild_mean(cold, acc, kcnt):
        """Owner-side merge: sum of the k updated replicas of each touched
        row plus (n - k) copies of the pre-step value, divided by n."""
        touched = kcnt[:, None] > 0
        return jnp.where(touched, (acc + (n - kcnt)[:, None] * cold) / n,
                         cold)

    def run_dense(hot_in, hot_out, cold_in, cold_out, step: StepInputs):
        me = jax.lax.axis_index(axis_name)
        ids_all = jax.lax.all_gather(step.cold_ids[0], axis_name)  # (n, R)
        valid = ids_all >= 0
        ci = jnp.where(valid, ids_all - hot, 0)
        mine = valid & (ci % n == me)
        lidx = jnp.where(mine, ci // n, 0)                         # (n, R)

        def gather(cold):
            served = jnp.where(mine[..., None], cold[lidx], 0.0)   # (n,R,d)
            return jax.lax.psum_scatter(
                served, axis_name, scatter_dimension=0, tiled=True)[0]

        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in, hot_out, gather(cold_in), gather(cold_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)

        tgt = jnp.where(mine, lidx, cps).reshape(-1)     # cps -> dropped
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            mine.reshape(-1).astype(jnp.float32), mode="drop")

        def write_back(cold, new_rows):
            upd_all = jax.lax.all_gather(new_rows, axis_name)      # (n,R,d)
            contrib = jnp.where(mine[..., None], upd_all, 0.0)
            acc = jnp.zeros_like(cold).at[tgt].add(
                contrib.reshape(-1, contrib.shape[-1]), mode="drop")
            return hogwild_mean(cold, acc, kcnt)

        cold_in_new = write_back(cold_in, new_got_in)
        cold_out_new = write_back(cold_out, new_got_out)
        return hot_in_new, hot_out_new, cold_in_new, cold_out_new

    def run_exact(hot_in, hot_out, cold_in, cold_out, step: StepInputs):
        r_width = step.cold_ids.shape[-1]                # R (static)
        req = step.bucket_ids[0]                         # (n, C) by owner
        pos = step.bucket_pos[0]                         # (n, C), pad = R
        # swap requester<->owner axes: got_req[s] = the bucket shard s
        # addressed to me — the only rows I must serve
        got_req = jax.lax.all_to_all(req, axis_name, 0, 0)
        serve = got_req >= 0
        lrow = jnp.where(serve, (got_req - hot) // n, 0)  # local rows

        def gather(cold):
            served = jnp.where(serve[..., None], cold[lrow], 0.0)  # (n,C,d)
            vals = jax.lax.all_to_all(served, axis_name, 0, 0)
            # vals[o, c] is the value of req[o, c]; land it at its first-
            # seen position in the gathered working block (pads drop)
            return jnp.zeros((r_width, cold.shape[-1]), cold.dtype).at[
                pos.reshape(-1)].set(
                    vals.reshape(-1, vals.shape[-1]), mode="drop")

        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in, hot_out, gather(cold_in), gather(cold_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)

        tgt = jnp.where(serve, lrow, cps).reshape(-1)    # cps -> dropped
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            serve.reshape(-1).astype(jnp.float32), mode="drop")
        reqv = req >= 0
        pos_c = jnp.where(reqv, pos, 0)

        def write_back(cold, new_rows):
            upd = jnp.where(reqv[..., None], new_rows[pos_c], 0.0)  # (n,C,d)
            back = jax.lax.all_to_all(upd, axis_name, 0, 0)
            # back[s] holds shard s's updated replicas of rows I own, in
            # the same slots as got_req[s]
            acc = jnp.zeros_like(cold).at[tgt].add(
                back.reshape(-1, back.shape[-1]), mode="drop")
            return hogwild_mean(cold, acc, kcnt)

        cold_in_new = write_back(cold_in, new_got_in)
        cold_out_new = write_back(cold_out, new_got_out)
        return hot_in_new, hot_out_new, cold_in_new, cold_out_new

    return run_exact if exchange == "exact" else run_dense
