"""Public jit'd entry points for the FULL-W2V kernel.

On TPU the Pallas kernel compiles natively; on CPU (this container) it runs
under ``interpret=True`` which executes the kernel body in Python — identical
semantics, correctness-only speed. ``backend="jnp"`` selects the pure-jnp
oracle (also the fastest option on CPU since it fully compiles).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fullw2v import fullw2v_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("w_f", "backend"),
                   donate_argnums=(0, 1))
def sgns_batch_update(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    tokens: jax.Array,    # (S, L) int32
    negs: jax.Array,      # (S, L, N) int32
    lengths: jax.Array,   # (S,) int32
    lr: jax.Array,        # scalar f32
    w_f: int,
    backend: str = "auto",   # auto | pallas | pallas_interpret | jnp
) -> Tuple[jax.Array, jax.Array]:
    """Train one batch of sentences with FULL-W2V semantics."""
    if backend == "auto":
        backend = "pallas_pipelined" if _on_tpu() else "jnp"
    if backend == "pallas":
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f)
    if backend == "pallas_pipelined":
        # §3.1 prefetch: negative/target rows for window t+1 DMA while
        # window t computes (hazard-safe; see kernels.fullw2v)
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f,
                              pipeline=True)
    if backend == "pallas_interpret":
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f,
                              interpret=True)
    if backend == "jnp":
        return _ref.batch_sgns_ref(w_in, w_out, tokens, negs, lengths,
                                   jnp.asarray(lr, jnp.float32), w_f)
    raise ValueError(f"unknown backend {backend!r}")
