"""Public jit'd entry points for the FULL-W2V kernel.

On TPU the Pallas kernel compiles natively; on CPU (this container) it runs
under ``interpret=True`` which executes the kernel body in Python — identical
semantics, correctness-only speed. ``backend="jnp"`` selects the pure-jnp
oracle (also the fastest option on CPU since it fully compiles).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fullw2v import fullw2v_pallas, fullw2v_pallas_tiled


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("w_f", "backend"),
                   donate_argnums=(0, 1))
def sgns_batch_update(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    tokens: jax.Array,    # (S, L) int32
    negs: jax.Array,      # (S, L, N) int32
    lengths: jax.Array,   # (S,) int32
    lr: jax.Array,        # scalar f32
    w_f: int,
    backend: str = "auto",   # auto | pallas | pallas_interpret | jnp
) -> Tuple[jax.Array, jax.Array]:
    """Train one batch of sentences with FULL-W2V semantics."""
    if backend == "auto":
        backend = "pallas_pipelined" if _on_tpu() else "jnp"
    if backend == "pallas":
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f)
    if backend == "pallas_pipelined":
        # §3.1 prefetch: negative/target rows for window t+1 DMA while
        # window t computes (hazard-safe; see kernels.fullw2v)
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f,
                              pipeline=True)
    if backend == "pallas_interpret":
        return fullw2v_pallas(w_in, w_out, tokens, negs, lengths,
                              jnp.asarray(lr, jnp.float32), w_f,
                              interpret=True)
    if backend == "jnp":
        return _ref.batch_sgns_ref(w_in, w_out, tokens, negs, lengths,
                                   jnp.asarray(lr, jnp.float32), w_f)
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.jit,
                   static_argnames=("w_f", "tile", "backend",
                                    "gemm_windows"),
                   donate_argnums=(0, 1))
def sgns_batch_update_tiled(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    tokens: jax.Array,    # (S, L) int32
    negs: jax.Array,      # (S, L, N) int32
    lengths: jax.Array,   # (S,) int32
    lr: jax.Array,        # scalar f32
    w_f: int,
    tile: int,
    uniq: jax.Array,      # (S, nt, T*(N+1)) int32 — plan_tiles output
    scatter: jax.Array,   # (S, nt, T*(N+1)) int32
    ucount: jax.Array,    # (S, nt) int32
    strict: jax.Array,    # (S, nt) int32
    backend: str = "auto",   # auto | pallas_tiled | pallas_tiled_interpret
                             # | jnp_tiled
    gemm_windows: int = 0,   # windows per GEMM group; 0 -> min(tile, 4)
) -> Tuple[jax.Array, jax.Array]:
    """Train one batch with T windows fused per kernel step (DESIGN.md §4).

    The tile schedule (uniq/scatter/ucount/strict) must come from
    ``repro.data.batching.plan_tiles`` for this exact batch; the host side
    owns conflict detection, exactly as the paper assigns negative
    preparation to the CPU. At ``tile=1`` every backend is bit-identical to
    the sequential path. ``gemm_windows`` bounds intra-tile staleness (see
    `fullw2v.fullw2v_pallas_tiled`).
    """
    lr = jnp.asarray(lr, jnp.float32)
    if backend == "auto":
        backend = "pallas_tiled" if _on_tpu() else "jnp_tiled"
    if backend == "pallas_tiled":
        return fullw2v_pallas_tiled(w_in, w_out, tokens, negs, lengths, lr,
                                    w_f, tile, uniq, scatter, ucount, strict,
                                    gemm_windows=gemm_windows)
    if backend == "pallas_tiled_interpret":
        return fullw2v_pallas_tiled(w_in, w_out, tokens, negs, lengths, lr,
                                    w_f, tile, uniq, scatter, ucount, strict,
                                    gemm_windows=gemm_windows,
                                    interpret=True)
    if backend == "jnp_tiled":
        return _ref.batch_sgns_tiled_ref(w_in, w_out, tokens, negs, lengths,
                                         lr, w_f, tile, uniq, scatter,
                                         ucount, strict,
                                         gemm_windows=gemm_windows)
    raise ValueError(f"unknown tiled backend {backend!r}")


_TILED_BACKEND = {
    # sequential backend name -> tiled equivalent (trainer dispatch)
    "auto": "auto",
    "pallas": "pallas_tiled",
    "pallas_pipelined": "pallas_tiled",
    "pallas_interpret": "pallas_tiled_interpret",
    "jnp": "jnp_tiled",
    "pallas_tiled": "pallas_tiled",
    "pallas_tiled_interpret": "pallas_tiled_interpret",
    "jnp_tiled": "jnp_tiled",
}


def tiled_backend(backend: str) -> str:
    """Map a sequential backend name to its tiled counterpart."""
    try:
        return _TILED_BACKEND[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}") from None
