"""Public entry point for the FULL-W2V kernel family (engine API).

One function — :func:`sgns_update` — replaces the old pair of jit'd
dispatchers (``sgns_batch_update`` / ``sgns_batch_update_tiled``) and the
hand-maintained sequential→tiled name map. Backend selection is data
driven: every kernel variant registers a capability descriptor in
``repro.kernels.registry`` and an ``update(w_in, w_out, step, static)``
implementation; resolution ("auto", tiled mapping, invalid combinations)
happens once against those descriptors.

Registered backends:

* ``jnp`` / ``jnp_tiled`` — the pure-jnp oracles (``kernels.ref``). Fully
  compiled, so also the fastest option on CPU.
* ``pallas`` / ``pallas_pipelined`` — the sequential Pallas kernel
  (``kernels.fullw2v``), the pipelined form adding §3.1 prefetch (window
  t+1's rows DMA while window t computes). TPU-native only.
* ``pallas_tiled`` — the window-tiled Pallas kernel (T windows fused per
  step, DESIGN.md §4). Consumes the host tile schedule carried in
  ``StepInputs.plan_*``. TPU-native only.
* ``pallas_interpret`` / ``pallas_tiled_interpret`` — the same kernels
  under ``interpret=True``: the kernel body executes in Python — identical
  semantics, correctness-only speed. What CI runs in this container.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.w2v import W2VConfig, resolve_gemm_windows
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels.fullw2v import fullw2v_pallas, fullw2v_pallas_tiled
from repro.kernels.registry import (KernelBackend, KernelStatic, StepInputs,
                                    register)


# ---------------------------------------------------------------------------
# Backend update() implementations (traceable; jit applied by the engine)
# ---------------------------------------------------------------------------

def _seq_args(step: StepInputs):
    return (step.tokens, step.negs, step.lengths,
            jnp.asarray(step.lr, jnp.float32))


def _tiled_args(step: StepInputs, static: KernelStatic):
    assert step.has_plan, "tiled backend requires StepInputs.plan_*"
    return (*_seq_args(step), static.w_f, static.tile, step.plan_uniq,
            step.plan_scatter, step.plan_ucount, step.plan_strict)


def _update_jnp(w_in, w_out, step, static):
    return _ref.batch_sgns_ref(w_in, w_out, *_seq_args(step), static.w_f)


def _update_pallas(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f)


def _update_pallas_pipelined(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          pipeline=True)


def _update_pallas_interpret(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          interpret=True)


def _update_jnp_tiled(w_in, w_out, step, static):
    return _ref.batch_sgns_tiled_ref(w_in, w_out,
                                     *_tiled_args(step, static),
                                     gemm_windows=static.gemm_windows)


def _update_pallas_tiled(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows)


def _update_pallas_tiled_interpret(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows,
                                interpret=True)


register(KernelBackend(
    name="jnp", update=_update_jnp,
    description="compiled jnp oracle (kernels.ref.batch_sgns_ref)",
    supports_tiling=True, tiled_variant="jnp_tiled"))
register(KernelBackend(
    name="pallas", update=_update_pallas,
    description="sequential Pallas kernel (TPU-native)",
    requires_tpu=True, supports_tiling=True, tiled_variant="pallas_tiled",
    interpret_variant="pallas_interpret"))
register(KernelBackend(
    name="pallas_pipelined", update=_update_pallas_pipelined,
    description="sequential Pallas kernel with §3.1 prefetch (TPU-native)",
    requires_tpu=True, supports_pipeline=True, supports_tiling=True,
    tiled_variant="pallas_tiled", interpret_variant="pallas_interpret"))
register(KernelBackend(
    name="pallas_interpret", update=_update_pallas_interpret,
    description="sequential Pallas kernel, interpret mode (any platform)",
    supports_tiling=True, tiled_variant="pallas_tiled_interpret"))
register(KernelBackend(
    name="jnp_tiled", update=_update_jnp_tiled,
    description="window-tiled jnp oracle (kernels.ref.batch_sgns_tiled_ref)",
    needs_plan=True))
register(KernelBackend(
    name="pallas_tiled", update=_update_pallas_tiled,
    description="window-tiled Pallas kernel (TPU-native, DESIGN.md §4)",
    needs_plan=True, requires_tpu=True,
    interpret_variant="pallas_tiled_interpret"))
register(KernelBackend(
    name="pallas_tiled_interpret", update=_update_pallas_tiled_interpret,
    description="window-tiled Pallas kernel, interpret mode (any platform)",
    needs_plan=True))


# ---------------------------------------------------------------------------
# The single dispatch entry point
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_update(name: str, static: KernelStatic):
    return jax.jit(traceable_update(name, static), donate_argnums=(0, 1))


def static_for(cfg: W2VConfig, tile: int = 1) -> KernelStatic:
    """The static kernel parameters for this config at tile size T."""
    return KernelStatic(
        w_f=cfg.fixed_window, tile=tile,
        gemm_windows=(resolve_gemm_windows(tile, cfg.tile_gemm_windows)
                      if tile > 1 else 0))


def traceable_update(backend: str, static: KernelStatic):
    """The resolved backend's raw traceable ``(w_in, w_out, step) ->
    (w_in, w_out)`` update — for callers that embed it in their own jit or
    shard_map (the trainer's Hogwild data-parallel step)."""
    be = registry.get(backend)

    def run(w_in: jax.Array, w_out: jax.Array, step: StepInputs):
        return be.update(w_in, w_out, step, static)

    return run


def sgns_update(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    step: StepInputs,     # tokens/negs/lengths/lr (+ tile plan if T > 1)
    cfg: W2VConfig,
    backend: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Train one batch of sentences with FULL-W2V semantics.

    The backend name resolves against the registry for this step's shape:
    ``step.has_plan`` selects the window-tiled kernel family (T windows
    fused per step, DESIGN.md §4; bit-identical to sequential at T=1), a
    plain step the sequential family. Tile size and GEMM grouping are
    static, derived from the plan shape and ``cfg.tile_gemm_windows``.
    """
    be = registry.resolve(backend, tiled=step.has_plan)
    return _jitted_update(be.name, static_for(cfg, step.tile))(
        w_in, w_out, step)
