"""Public entry point for the FULL-W2V kernel family (engine API).

One function — :func:`step` — trains one batch against a
:class:`~repro.kernels.tables.Tables` pytree and resolves *everything*
from its :class:`~repro.kernels.tables.TableSpec`: replicated vs
vocab-sharded dispatch, the exchange flavor (request-exact ``all_to_all``
buckets vs the dense reference), and the storage precision of every
table. Backend selection is data driven: every kernel variant registers a
capability descriptor in ``repro.kernels.registry`` and an
``update(w_in, w_out, step, static)`` implementation; resolution
("auto", tiled mapping, dtype capability, invalid combinations) happens
once against those descriptors.

Registered backends:

* ``jnp`` / ``jnp_tiled`` — the pure-jnp oracles (``kernels.ref``). Fully
  compiled, so also the fastest option on CPU.
* ``pallas`` / ``pallas_pipelined`` — the sequential Pallas kernel
  (``kernels.fullw2v``), the pipelined form adding §3.1 prefetch (window
  t+1's rows DMA while window t computes). TPU-native only.
* ``pallas_tiled`` — the window-tiled Pallas kernel (T windows fused per
  step, DESIGN.md §4). Consumes the host tile schedule carried in
  ``StepInputs.plan_*``. TPU-native only.
* ``pallas_interpret`` / ``pallas_tiled_interpret`` — the same kernels
  under ``interpret=True``: the kernel body executes in Python — identical
  semantics, correctness-only speed. What CI runs in this container.

Mixed-precision storage (DESIGN.md §11): tables stored in ``bfloat16`` /
``int8`` dequantize to f32 at the working-set boundary (VMEM on
hardware), the window-tile update math runs unchanged in f32, and results
store back with *keyed stochastic rounding* (``kernels.quant`` — keys are
pure functions of ``(seed, epoch, batch_index)``, so runs stay
bit-deterministic at any worker count and through chaos recovery). In the
vocab-sharded exchange the cold rows travel *quantized* — int8 payload +
per-row f32 scale, or bf16 — which is where the 2×/4× §8 exchange-byte
reduction comes from. Backends whose kernels can't consume a storage
dtype (``supports_dtypes``) still run it under the f32 master-copy
fallback (``TableSpec.master_copy``): decode → unmodified f32 step →
stochastic re-encode, correct but without the transport win.

``sgns_update`` / ``vocab_sharded_update`` remain as deprecated shims
that warn and forward.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.w2v import W2VConfig, resolve_gemm_windows
from repro.kernels import quant
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels.fullw2v import (fullw2v_pallas, fullw2v_pallas_tiled,
                                   fullw2v_pallas_tiled_fused)
from repro.kernels.registry import (KernelBackend, KernelStatic, StepInputs,
                                    register)
from repro.kernels.tables import Tables, TableSpec


# ---------------------------------------------------------------------------
# Backend update() implementations (traceable; jit applied by the engine)
# ---------------------------------------------------------------------------

def _seq_args(step: StepInputs):
    return (step.tokens, step.negs, step.lengths,
            jnp.asarray(step.lr, jnp.float32))


def _tiled_args(step: StepInputs, static: KernelStatic):
    assert step.has_plan, "tiled backend requires StepInputs.plan_*"
    return (*_seq_args(step), static.w_f, static.tile, step.plan_uniq,
            step.plan_scatter, step.plan_ucount, step.plan_strict)


def _update_jnp(w_in, w_out, step, static):
    return _ref.batch_sgns_ref(w_in, w_out, *_seq_args(step), static.w_f,
                               static_ids=step.static_ctx, bags=step.bags)


def _update_pallas(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f)


def _update_pallas_pipelined(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          pipeline=True)


def _update_pallas_interpret(w_in, w_out, step, static):
    return fullw2v_pallas(w_in, w_out, *_seq_args(step), static.w_f,
                          interpret=True)


def _update_jnp_tiled(w_in, w_out, step, static):
    return _ref.batch_sgns_tiled_ref(w_in, w_out,
                                     *_tiled_args(step, static),
                                     gemm_windows=static.gemm_windows,
                                     static_ids=step.static_ctx,
                                     bags=step.bags)


def _update_pallas_tiled(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows)


def _update_pallas_tiled_interpret(w_in, w_out, step, static):
    return fullw2v_pallas_tiled(w_in, w_out, *_tiled_args(step, static),
                                gemm_windows=static.gemm_windows,
                                interpret=True)


def _update_fused_pallas_tiled(hot_in, hot_out, got_in, got_out, step, static):
    return fullw2v_pallas_tiled_fused(hot_in, hot_out, got_in, got_out,
                                      *_tiled_args(step, static),
                                      gemm_windows=static.gemm_windows)


def _update_fused_pallas_tiled_interpret(hot_in, hot_out, got_in, got_out,
                                         step, static):
    return fullw2v_pallas_tiled_fused(hot_in, hot_out, got_in, got_out,
                                      *_tiled_args(step, static),
                                      gemm_windows=static.gemm_windows,
                                      interpret=True)


# storage-dtype capability (DESIGN.md §11): the engine dequantizes at the
# working-set boundary, so capability is about what the *kernel's* DMA
# stream can consume on hardware — the native Pallas kernels take bf16
# rows (VMEM converts on load), int8 row+scale decode is implemented in
# the jnp/interpret engines only; native-TPU int8 needs the master-copy
# fallback until a dequantizing DMA path lands (ROADMAP item 2's lane).
_ALL_DTYPES = ("float32", "bfloat16", "int8")
_NATIVE_DTYPES = ("float32", "bfloat16")

# only the jnp oracles consume the workload-frontend StepInputs extensions
# (DESIGN.md §12) so far; the Pallas kernels' DMA schedules don't route
# bag members or the static doc row yet (ROADMAP)
_FRONTENDS = ("static_ctx", "bags")

register(KernelBackend(
    name="jnp", update=_update_jnp,
    description="compiled jnp oracle (kernels.ref.batch_sgns_ref)",
    supports_tiling=True, supports_vocab_shard=True,
    supports_dtypes=_ALL_DTYPES,
    supports_frontends=_FRONTENDS,
    tiled_variant="jnp_tiled"))
register(KernelBackend(
    name="pallas", update=_update_pallas,
    description="sequential Pallas kernel (TPU-native)",
    requires_tpu=True, supports_tiling=True, supports_vocab_shard=True,
    supports_dtypes=_NATIVE_DTYPES,
    tiled_variant="pallas_tiled", interpret_variant="pallas_interpret"))
# pallas_pipelined opts OUT of vocab sharding: its §3.1 prefetch exists to
# hide HBM row latency, but a vocab-sharded step hands the kernel a compact
# VMEM-sized working table — prefetch buys nothing there, so the capable
# variant is plain `pallas` (and "auto" resolves to it).
register(KernelBackend(
    name="pallas_pipelined", update=_update_pallas_pipelined,
    description="sequential Pallas kernel with §3.1 prefetch (TPU-native)",
    requires_tpu=True, supports_pipeline=True, supports_tiling=True,
    supports_dtypes=_NATIVE_DTYPES,
    tiled_variant="pallas_tiled", interpret_variant="pallas_interpret"))
register(KernelBackend(
    name="pallas_interpret", update=_update_pallas_interpret,
    description="sequential Pallas kernel, interpret mode (any platform)",
    supports_tiling=True, supports_vocab_shard=True,
    supports_dtypes=_ALL_DTYPES,
    tiled_variant="pallas_tiled_interpret"))
register(KernelBackend(
    name="jnp_tiled", update=_update_jnp_tiled,
    description="window-tiled jnp oracle (kernels.ref.batch_sgns_tiled_ref)",
    needs_plan=True, supports_vocab_shard=True,
    supports_dtypes=_ALL_DTYPES,
    supports_frontends=_FRONTENDS))
register(KernelBackend(
    name="pallas_tiled", update=_update_pallas_tiled,
    description="window-tiled Pallas kernel (TPU-native, DESIGN.md §4)",
    needs_plan=True, requires_tpu=True, supports_vocab_shard=True,
    supports_dtypes=_NATIVE_DTYPES,
    interpret_variant="pallas_tiled_interpret",
    update_fused=_update_fused_pallas_tiled))
register(KernelBackend(
    name="pallas_tiled_interpret", update=_update_pallas_tiled_interpret,
    description="window-tiled Pallas kernel, interpret mode (any platform)",
    needs_plan=True, supports_vocab_shard=True,
    supports_dtypes=_ALL_DTYPES,
    update_fused=_update_fused_pallas_tiled_interpret))


# ---------------------------------------------------------------------------
# The single dispatch entry point
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_update(name: str, static: KernelStatic):
    return jax.jit(traceable_update(name, static), donate_argnums=(0, 1))


def static_for(cfg: W2VConfig, tile: int = 1) -> KernelStatic:
    """The static kernel parameters for this config at tile size T."""
    return KernelStatic(
        w_f=cfg.fixed_window, tile=tile,
        gemm_windows=(resolve_gemm_windows(tile, cfg.tile_gemm_windows)
                      if tile > 1 else 0))


def traceable_update(backend: str, static: KernelStatic):
    """The resolved backend's raw traceable ``(w_in, w_out, step) ->
    (w_in, w_out)`` update — for callers that embed it in their own jit or
    shard_map."""
    be = registry.get(backend)

    def run(w_in: jax.Array, w_out: jax.Array, step: StepInputs):
        return be.update(w_in, w_out, step, static)

    return run


@functools.lru_cache(maxsize=None)
def _jitted_mixed_update(name: str, static: KernelStatic, dtype: str):
    """Replicated full-table step for sub-f32 storage: decode → unchanged
    f32 update → keyed stochastic re-encode. Values already representable
    in the storage dtype round-trip exactly, so rows the batch never
    touches do not drift."""
    be = registry.get(name)

    def run(w_in, w_out, step: StepInputs):
        new_in, new_out = be.update(w_in.astype(jnp.float32),
                                    w_out.astype(jnp.float32), step, static)
        k = step.round_key
        new_in, _ = quant.encode_stochastic(new_in, dtype, k,
                                            quant.TAG_FULL_IN)
        new_out, _ = quant.encode_stochastic(new_out, dtype, k,
                                             quant.TAG_FULL_OUT)
        return new_in, new_out

    return jax.jit(run, donate_argnums=(0, 1))


@functools.lru_cache(maxsize=None)
def _jitted_dp_update(name: str, static: KernelStatic, dtype: str,
                      mesh: Mesh, axis_name: str, has_plan: bool,
                      has_key: bool, has_doc: bool = False,
                      has_bags: bool = False):
    """The Hogwild data-parallel step: sentences (and tile-plan rows)
    shard over ``axis_name``, each shard updates a local replica, replicas
    pmean-average. Sub-f32 storage decodes before and stochastically
    re-encodes after the average — the key is replicated, so every shard
    rounds the identical averaged values to the identical storage bits."""
    from jax.experimental.shard_map import shard_map

    local = traceable_update(name, static)
    mixed = dtype != "float32"

    def local_update(w_in, w_out, step: StepInputs):
        if mixed:
            w_in = w_in.astype(jnp.float32)
            w_out = w_out.astype(jnp.float32)
        new_in, new_out = local(w_in, w_out, step)
        new_in = jax.lax.pmean(new_in, axis_name)
        new_out = jax.lax.pmean(new_out, axis_name)
        if mixed:
            k = step.round_key
            new_in, _ = quant.encode_stochastic(new_in, dtype, k,
                                                quant.TAG_FULL_IN)
            new_out, _ = quant.encode_stochastic(new_out, dtype, k,
                                                 quant.TAG_FULL_OUT)
        return new_in, new_out

    plan_spec = P(axis_name) if has_plan else None
    step_specs = StepInputs(
        tokens=P(axis_name), negs=P(axis_name), lengths=P(axis_name), lr=P(),
        plan_uniq=plan_spec, plan_scatter=plan_spec,
        plan_ucount=plan_spec, plan_strict=plan_spec,
        round_key=P() if has_key else None,
        static_ctx=P(axis_name) if has_doc else None,
        bags=P(axis_name) if has_bags else None)
    sharded = shard_map(
        local_update, mesh=mesh,
        in_specs=(P(), P(), step_specs),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(0, 1))


@functools.lru_cache(maxsize=None)
def _jitted_vs_update(name: str, static: KernelStatic, spec: TableSpec,
                      placement, mesh: Mesh, axis_name: str,
                      has_doc: bool = False, has_bags: bool = False):
    """The vocab-sharded step under shard_map: hot replicas P(), cold
    tables (and int8 scales) row-sharded over ``axis_name``, the exchange
    plan sharded by requester."""
    from jax.experimental.shard_map import shard_map

    run = _vocab_sharded_run(name, static, placement, axis_name=axis_name,
                             exchange=spec.exchange, spec=spec)
    plan_spec = P(axis_name) if static.tile > 1 else None
    step_specs = StepInputs(
        tokens=P(axis_name), negs=P(axis_name), lengths=P(axis_name), lr=P(),
        plan_uniq=plan_spec, plan_scatter=plan_spec,
        plan_ucount=plan_spec, plan_strict=plan_spec,
        cold_ids=P(axis_name), bucket_ids=P(axis_name),
        bucket_pos=P(axis_name),
        round_key=P() if spec.is_mixed else None,
        static_ctx=P(axis_name) if has_doc else None,
        bags=P(axis_name) if has_bags else None)
    scale_spec = P(axis_name) if spec.needs_scales else None
    sharded = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P(axis_name), P(axis_name),
                  scale_spec, scale_spec, step_specs),
        out_specs=(P(), P(), P(axis_name), P(axis_name),
                   scale_spec, scale_spec),
        check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(0, 1, 2, 3, 4, 5))


def step(tables: Tables, step: StepInputs, cfg: W2VConfig,
         backend: str = "auto", mesh=None, axis_name: str = "data"
         ) -> Tables:
    """Train one batch of sentences with FULL-W2V semantics.

    The one engine entry point: everything the old ``sgns_update`` /
    ``vocab_sharded_update`` pair resolved by call-site choice now comes
    from ``tables.spec`` (a :class:`~repro.kernels.tables.TableSpec`) and
    the step's shape:

    * ``tables.placement`` set → the vocab-sharded path (DESIGN.md §8):
      the step must carry an exchange plan (``step.cold_ids`` /
      ``bucket_*`` from ``distributed.vocab_placement.plan_exchange``) and
      a ``mesh``; ``spec.exchange`` picks request-exact ``all_to_all``
      buckets or the dense reference collectives.
    * no placement, ``mesh`` given → Hogwild data parallelism (sentences
      shard, replicas pmean-average).
    * neither → the plain single-replica jit.

    ``step.has_plan`` selects the window-tiled kernel family in every
    case (bit-identical to sequential at T=1). Sub-f32 storage dtypes in
    the spec run decode → f32 update → keyed stochastic re-encode
    (``step.round_key`` required; see ``kernels.quant``); the backend must
    support the dtypes (``registry.resolve(dtypes=...)`` — actionable
    error otherwise) unless ``spec.master_copy`` opts into the f32
    fallback. Returns a new :class:`Tables` with the same spec/placement.
    """
    spec = tables.spec
    if spec.is_mixed and step.round_key is None:
        raise ValueError(
            "TableSpec stores a table below f32 but StepInputs.round_key "
            "is None; attach quant.round_key(cfg.seed, epoch, batch_index) "
            "so stochastic rounding stays bit-deterministic")
    dtypes = () if spec.master_copy else spec.dtypes
    frontends = ((("static_ctx",) if step.has_static_ctx else ())
                 + (("bags",) if step.has_bags else ()))
    if tables.placement is not None:
        if not step.has_vocab_shard:
            raise ValueError(
                "Tables carry a VocabPlacement but StepInputs has no "
                "exchange plan (cold_ids); build the step via "
                "distributed.vocab_placement.plan_exchange")
        if mesh is None:
            raise ValueError(
                "vocab-sharded Tables run under shard_map; pass the "
                "session mesh (a 1-device Mesh works for one shard)")
        be = registry.resolve(backend, tiled=step.has_plan,
                              vocab_shard=True, dtypes=dtypes,
                              frontends=frontends)
        fn = _jitted_vs_update(be.name, static_for(cfg, step.tile), spec,
                               tables.placement, mesh, axis_name,
                               step.has_static_ctx, step.has_bags)
        w_in, w_out, cold_in, cold_out, scale_in, scale_out = fn(
            tables.w_in, tables.w_out, tables.cold_in, tables.cold_out,
            tables.scale_in, tables.scale_out, step)
        return dataclasses.replace(
            tables, w_in=w_in, w_out=w_out, cold_in=cold_in,
            cold_out=cold_out, scale_in=scale_in, scale_out=scale_out)
    if step.has_vocab_shard:
        raise ValueError(
            "StepInputs carries a vocab-sharding exchange plan (cold_ids); "
            "this is the single-replica entry point. Run the step "
            "through a mesh TrainSession with cfg.vocab_shard=True, or "
            "build the step without plan_exchange.")
    be = registry.resolve(backend, tiled=step.has_plan, dtypes=dtypes,
                          frontends=frontends)
    static = static_for(cfg, step.tile)
    if mesh is not None:
        fn = _jitted_dp_update(be.name, static, spec.hot_dtype, mesh,
                               axis_name, step.has_plan,
                               step.round_key is not None,
                               step.has_static_ctx, step.has_bags)
        w_in, w_out = fn(tables.w_in, tables.w_out, step)
    elif spec.hot_dtype == "float32":
        w_in, w_out = _jitted_update(be.name, static)(
            tables.w_in, tables.w_out, step)
    else:
        w_in, w_out = _jitted_mixed_update(be.name, static, spec.hot_dtype)(
            tables.w_in, tables.w_out, step)
    return dataclasses.replace(tables, w_in=w_in, w_out=w_out)


_step = step   # module-level alias: the shims' `step` parameter shadows it


# ---------------------------------------------------------------------------
# Vocab-sharded runner (DESIGN.md §8): hot replica + cold shard exchange
# ---------------------------------------------------------------------------

def _vocab_sharded_run(backend: str, static: KernelStatic, placement,
                       axis_name: str = "data", exchange: str = "exact",
                       spec: TableSpec = TableSpec()):
    """The per-shard update for vocab-sharded tables, to run under
    ``shard_map`` over ``axis_name``.

    Signature of the returned function (all arguments are the *local*
    blocks shard_map hands each device):

        run(hot_in, hot_out, cold_in, cold_out, scale_in, scale_out, step)
            -> (hot_in', hot_out', cold_in', cold_out',
                scale_in', scale_out')

    where ``hot_*`` are the replicated ``(hot, d)`` head tables, ``cold_*``
    the local ``(cold_per_shard, d)`` shard of the striped cold tail
    (stored in ``spec.cold_dtype``), ``scale_*`` the per-row int8 scales
    (``None`` unless ``spec.needs_scales``), and ``step`` a
    :class:`~repro.kernels.registry.StepInputs` built by
    ``distributed.vocab_placement.plan_exchange``.

    One step does, entirely on-device (DESIGN.md §8 exchange math):

    1. **Gather** (``exchange="exact"``, the default) — ``all_to_all`` the
       per-owner request buckets (ints, O(n·C) ≈ O(R)), serve the rows
       this shard owns *in storage precision* (int8 payload + per-row
       scale, bf16, or f32), ``all_to_all`` the values back, decode to
       f32, and scatter into request order via the host-planned bucket
       positions: every shard sends and receives O(R·d·itemsize) bytes —
       request-exact and precision-proportional. ``exchange="dense"``
       keeps the PR 5 all_gather + ``psum_scatter`` path (f32, O(n·R·d)
       per device) as the parity reference.
    2. **Compute** — run the resolved backend on the compact f32 working
       table of ``hot + R`` rows: backends declaring
       ``supports_fused_gather`` are handed the hot replica and the
       gathered block as *separate* buffers; the rest run unchanged on
       ``concat(hot, gathered)``.
    3. **Write back** — pmean the hot head across shards (Hogwild
       averaging; bf16 heads then stochastic-round identically on every
       shard — the round key is replicated). Updated request rows route
       back to their owners (transport-quantized round-to-nearest on the
       exact path), are decoded and scatter-added, and each touched row
       averages over all ``n`` replicas' contributions before
       re-encoding to storage with keyed stochastic rounding (key folded
       with the owner's axis index). Untouched rows keep their exact
       storage bytes (``where`` on the touched mask).

    With ``spec.master_copy`` and a backend that lacks the storage
    dtypes, the whole f32 path runs between a full decode and a full
    stochastic re-encode instead — correct everywhere, no transport win.
    """
    be = registry.get(backend)
    if not be.supports_vocab_shard:
        raise ValueError(
            f"backend {backend!r} does not support vocab-sharded tables; "
            f"resolve with vocab_shard=True to get an actionable choice")
    if exchange not in ("exact", "dense"):
        raise ValueError(f"exchange must be 'exact' or 'dense', "
                         f"got {exchange!r}")
    hot = placement.hot
    cps = placement.cold_per_shard
    n = placement.n_shards
    hot_dt, cold_dt = spec.hot_dtype, spec.cold_dtype
    native = all(d in be.supports_dtypes for d in spec.dtypes)

    def compute(hot_in, hot_out, got_in, got_out, step):
        """Run the backend on the working table; return (new_hot_in,
        new_hot_out, new_got_in, new_got_out)."""
        if be.supports_fused_gather:
            return be.update_fused(hot_in, hot_out, got_in, got_out,
                                   step, static)
        w_in_work = jnp.concatenate([hot_in, got_in], axis=0)
        w_out_work = jnp.concatenate([hot_out, got_out], axis=0)
        new_in, new_out = be.update(w_in_work, w_out_work, step, static)
        return new_in[:hot], new_out[:hot], new_in[hot:], new_out[hot:]

    def hogwild_mean(cold, acc, kcnt):
        """Owner-side merge: sum of the k updated replicas of each touched
        row plus (n - k) copies of the pre-step value, divided by n."""
        touched = kcnt[:, None] > 0
        return jnp.where(touched, (acc + (n - kcnt)[:, None] * cold) / n,
                         cold)

    # -- the f32 paths (bit-identical to the pre-TableSpec engine) ----------
    def run_dense_f32(hot_in, hot_out, cold_in, cold_out, step: StepInputs):
        me = jax.lax.axis_index(axis_name)
        ids_all = jax.lax.all_gather(step.cold_ids[0], axis_name)  # (n, R)
        valid = ids_all >= 0
        ci = jnp.where(valid, ids_all - hot, 0)
        mine = valid & (ci % n == me)
        lidx = jnp.where(mine, ci // n, 0)                         # (n, R)

        def gather(cold):
            served = jnp.where(mine[..., None], cold[lidx], 0.0)   # (n,R,d)
            return jax.lax.psum_scatter(
                served, axis_name, scatter_dimension=0, tiled=True)[0]

        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in, hot_out, gather(cold_in), gather(cold_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)

        tgt = jnp.where(mine, lidx, cps).reshape(-1)     # cps -> dropped
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            mine.reshape(-1).astype(jnp.float32), mode="drop")

        def write_back(cold, new_rows):
            upd_all = jax.lax.all_gather(new_rows, axis_name)      # (n,R,d)
            contrib = jnp.where(mine[..., None], upd_all, 0.0)
            acc = jnp.zeros_like(cold).at[tgt].add(
                contrib.reshape(-1, contrib.shape[-1]), mode="drop")
            return hogwild_mean(cold, acc, kcnt)

        cold_in_new = write_back(cold_in, new_got_in)
        cold_out_new = write_back(cold_out, new_got_out)
        return hot_in_new, hot_out_new, cold_in_new, cold_out_new

    def run_exact_f32(hot_in, hot_out, cold_in, cold_out, step: StepInputs):
        r_width = step.cold_ids.shape[-1]                # R (static)
        req = step.bucket_ids[0]                         # (n, C) by owner
        pos = step.bucket_pos[0]                         # (n, C), pad = R
        # swap requester<->owner axes: got_req[s] = the bucket shard s
        # addressed to me — the only rows I must serve
        got_req = jax.lax.all_to_all(req, axis_name, 0, 0)
        serve = got_req >= 0
        lrow = jnp.where(serve, (got_req - hot) // n, 0)  # local rows

        def gather(cold):
            served = jnp.where(serve[..., None], cold[lrow], 0.0)  # (n,C,d)
            vals = jax.lax.all_to_all(served, axis_name, 0, 0)
            # vals[o, c] is the value of req[o, c]; land it at its first-
            # seen position in the gathered working block (pads drop)
            return jnp.zeros((r_width, cold.shape[-1]), cold.dtype).at[
                pos.reshape(-1)].set(
                    vals.reshape(-1, vals.shape[-1]), mode="drop")

        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in, hot_out, gather(cold_in), gather(cold_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)

        tgt = jnp.where(serve, lrow, cps).reshape(-1)    # cps -> dropped
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            serve.reshape(-1).astype(jnp.float32), mode="drop")
        reqv = req >= 0
        pos_c = jnp.where(reqv, pos, 0)

        def write_back(cold, new_rows):
            upd = jnp.where(reqv[..., None], new_rows[pos_c], 0.0)  # (n,C,d)
            back = jax.lax.all_to_all(upd, axis_name, 0, 0)
            # back[s] holds shard s's updated replicas of rows I own, in
            # the same slots as got_req[s]
            acc = jnp.zeros_like(cold).at[tgt].add(
                back.reshape(-1, back.shape[-1]), mode="drop")
            return hogwild_mean(cold, acc, kcnt)

        cold_in_new = write_back(cold_in, new_got_in)
        cold_out_new = write_back(cold_out, new_got_out)
        return hot_in_new, hot_out_new, cold_in_new, cold_out_new

    run_f32 = run_exact_f32 if exchange == "exact" else run_dense_f32

    if not spec.is_mixed:
        def run_plain(hot_in, hot_out, cold_in, cold_out, scale_in,
                      scale_out, step):
            return (*run_f32(hot_in, hot_out, cold_in, cold_out, step),
                    None, None)
        return run_plain

    def requant_cold(merged, cold, scale, touched, key, tag):
        """Touched rows re-encode to storage with the keyed stochastic
        round (key folded with owner shard index so shards draw distinct
        noise); untouched rows keep their exact storage bytes."""
        k = jax.random.fold_in(jax.random.fold_in(key, tag),
                               jax.lax.axis_index(axis_name))
        if cold_dt == "int8":
            qn, sn = quant.int8_stochastic(merged, k)
            return (jnp.where(touched[:, None], qn, cold),
                    jnp.where(touched, sn, scale))
        if cold_dt == "bfloat16":
            bn = quant.bf16_stochastic(merged, k)
            return jnp.where(touched[:, None], bn, cold), None
        return merged, None

    def requant_hot(hot_in_new, hot_out_new, key):
        if hot_dt == "bfloat16":
            hot_in_new = quant.bf16_stochastic(
                hot_in_new, jax.random.fold_in(key, quant.TAG_HOT_IN))
            hot_out_new = quant.bf16_stochastic(
                hot_out_new, jax.random.fold_in(key, quant.TAG_HOT_OUT))
        return hot_in_new, hot_out_new

    if not native:
        # f32 master-copy fallback: full decode -> unmodified f32 path ->
        # full stochastic re-encode (whole blocks: correct and
        # deterministic, but cold rows re-encode every step and the
        # transport stays f32)
        def run_master(hot_in, hot_out, cold_in, cold_out, scale_in,
                       scale_out, step):
            k = step.round_key
            nhi, nho, nci, nco = run_f32(
                quant.decode(hot_in, None, hot_dt),
                quant.decode(hot_out, None, hot_dt),
                quant.decode(cold_in, scale_in, cold_dt),
                quant.decode(cold_out, scale_out, cold_dt), step)
            nhi, nho = requant_hot(nhi, nho, k)
            all_rows = jnp.ones((cps,), bool)
            nci, nsi = requant_cold(nci, cold_in, scale_in, all_rows, k,
                                    quant.TAG_COLD_IN)
            nco, nso = requant_cold(nco, cold_out, scale_out, all_rows, k,
                                    quant.TAG_COLD_OUT)
            return nhi, nho, nci, nco, nsi, nso
        return run_master

    # -- native mixed paths: quantized transport ----------------------------
    def run_dense_mixed(hot_in, hot_out, cold_in, cold_out, scale_in,
                        scale_out, step: StepInputs):
        me = jax.lax.axis_index(axis_name)
        ids_all = jax.lax.all_gather(step.cold_ids[0], axis_name)
        valid = ids_all >= 0
        ci = jnp.where(valid, ids_all - hot, 0)
        mine = valid & (ci % n == me)
        lidx = jnp.where(mine, ci // n, 0)
        k = step.round_key

        def gather(cold, scale):
            cold_f = quant.decode(cold, scale, cold_dt)
            served = jnp.where(mine[..., None], cold_f[lidx], 0.0)
            return jax.lax.psum_scatter(
                served, axis_name, scatter_dimension=0, tiled=True)[0]

        hot_in_f = hot_in.astype(jnp.float32) if hot_dt != "float32" \
            else hot_in
        hot_out_f = hot_out.astype(jnp.float32) if hot_dt != "float32" \
            else hot_out
        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in_f, hot_out_f, gather(cold_in, scale_in),
            gather(cold_out, scale_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)
        hot_in_new, hot_out_new = requant_hot(hot_in_new, hot_out_new, k)

        tgt = jnp.where(mine, lidx, cps).reshape(-1)
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            mine.reshape(-1).astype(jnp.float32), mode="drop")
        touched = kcnt > 0

        def write_back(cold, scale, new_rows, tag):
            upd_all = jax.lax.all_gather(new_rows, axis_name)
            contrib = jnp.where(mine[..., None], upd_all, 0.0)
            local_f = quant.decode(cold, scale, cold_dt)
            acc = jnp.zeros_like(local_f).at[tgt].add(
                contrib.reshape(-1, contrib.shape[-1]), mode="drop")
            merged = hogwild_mean(local_f, acc, kcnt)
            return requant_cold(merged, cold, scale, touched, k, tag)

        cold_in_new, scale_in_new = write_back(
            cold_in, scale_in, new_got_in, quant.TAG_COLD_IN)
        cold_out_new, scale_out_new = write_back(
            cold_out, scale_out, new_got_out, quant.TAG_COLD_OUT)
        return (hot_in_new, hot_out_new, cold_in_new, cold_out_new,
                scale_in_new, scale_out_new)

    def run_exact_mixed(hot_in, hot_out, cold_in, cold_out, scale_in,
                        scale_out, step: StepInputs):
        d = cold_in.shape[-1]
        r_width = step.cold_ids.shape[-1]
        req = step.bucket_ids[0]
        pos = step.bucket_pos[0]
        got_req = jax.lax.all_to_all(req, axis_name, 0, 0)
        serve = got_req >= 0
        lrow = jnp.where(serve, (got_req - hot) // n, 0)
        k = step.round_key

        def gather(cold, scale):
            # rows travel in storage precision: int8 payload + per-row f32
            # scale (d+4 bytes/row) or bf16 (2d) instead of f32 (4d) — the
            # §11 exchange-byte reduction
            if cold_dt == "int8":
                zero_q = jnp.zeros((), cold.dtype)
                sq = jnp.where(serve[..., None], cold[lrow], zero_q)
                ss = jnp.where(serve, scale[lrow], 0.0)
                vq = jax.lax.all_to_all(sq, axis_name, 0, 0)
                vs = jax.lax.all_to_all(ss, axis_name, 0, 0)
                vals = vq.astype(jnp.float32) * vs[..., None]
            else:
                zero = jnp.zeros((), cold.dtype)
                sv = jnp.where(serve[..., None], cold[lrow], zero)
                vals = jax.lax.all_to_all(
                    sv, axis_name, 0, 0).astype(jnp.float32)
            return jnp.zeros((r_width, d), jnp.float32).at[
                pos.reshape(-1)].set(vals.reshape(-1, d), mode="drop")

        hot_in_f = hot_in.astype(jnp.float32) if hot_dt != "float32" \
            else hot_in
        hot_out_f = hot_out.astype(jnp.float32) if hot_dt != "float32" \
            else hot_out
        hot_in_new, hot_out_new, new_got_in, new_got_out = compute(
            hot_in_f, hot_out_f, gather(cold_in, scale_in),
            gather(cold_out, scale_out), step)
        hot_in_new = jax.lax.pmean(hot_in_new, axis_name)
        hot_out_new = jax.lax.pmean(hot_out_new, axis_name)
        hot_in_new, hot_out_new = requant_hot(hot_in_new, hot_out_new, k)

        tgt = jnp.where(serve, lrow, cps).reshape(-1)
        kcnt = jnp.zeros((cps,), jnp.float32).at[tgt].add(
            serve.reshape(-1).astype(jnp.float32), mode="drop")
        touched = kcnt > 0
        reqv = req >= 0
        pos_c = jnp.where(reqv, pos, 0)

        def write_back(cold, scale, new_rows, tag):
            upd = jnp.where(reqv[..., None], new_rows[pos_c], 0.0)
            # transport quantization is *nearest* (deterministic): the
            # value is re-rounded at the storage seam anyway, stochastic
            # noise here would just widen the hogwild average
            if cold_dt == "int8":
                ts = quant.int8_scale(upd)                      # (n, C)
                tq, _ = quant.int8_nearest(upd, ts)
                bq = jax.lax.all_to_all(tq, axis_name, 0, 0)
                bs = jax.lax.all_to_all(ts, axis_name, 0, 0)
                back = bq.astype(jnp.float32) * bs[..., None]
            elif cold_dt == "bfloat16":
                back = jax.lax.all_to_all(
                    upd.astype(jnp.bfloat16), axis_name, 0, 0
                ).astype(jnp.float32)
            else:
                back = jax.lax.all_to_all(upd, axis_name, 0, 0)
            local_f = quant.decode(cold, scale, cold_dt)
            acc = jnp.zeros((cps, d), jnp.float32).at[tgt].add(
                back.reshape(-1, d), mode="drop")
            merged = hogwild_mean(local_f, acc, kcnt)
            return requant_cold(merged, cold, scale, touched, k, tag)

        cold_in_new, scale_in_new = write_back(
            cold_in, scale_in, new_got_in, quant.TAG_COLD_IN)
        cold_out_new, scale_out_new = write_back(
            cold_out, scale_out, new_got_out, quant.TAG_COLD_OUT)
        return (hot_in_new, hot_out_new, cold_in_new, cold_out_new,
                scale_in_new, scale_out_new)

    return run_exact_mixed if exchange == "exact" else run_dense_mixed


# ---------------------------------------------------------------------------
# Deprecated shims (warn and forward)
# ---------------------------------------------------------------------------

def sgns_update(
    w_in: jax.Array,      # (V, d) f32 — donated
    w_out: jax.Array,     # (V, d) f32 — donated
    step: StepInputs,     # tokens/negs/lengths/lr (+ tile plan if T > 1)
    cfg: W2VConfig,
    backend: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Deprecated: use :func:`step` with a plain f32 ``Tables``.

    Kept as a thin shim — warns and forwards to the unified entry point
    with ``Tables(w_in=w_in, w_out=w_out)`` (an f32 replicated spec), so
    existing callers keep their exact semantics, including the rejection
    of vocab-sharded steps.
    """
    warnings.warn(
        "ops.sgns_update is deprecated; use ops.step(Tables(w_in=..., "
        "w_out=...), step, cfg, backend=...) — the TableSpec-driven entry "
        "point that also covers sharded and mixed-precision tables",
        DeprecationWarning, stacklevel=2)
    out = _step(Tables(w_in=w_in, w_out=w_out), step, cfg, backend=backend)
    return out.w_in, out.w_out


def vocab_sharded_update(backend: str, static: KernelStatic, placement,
                         axis_name: str = "data", exchange: str = "exact"):
    """Deprecated: use :func:`step` with vocab-sharded ``Tables``.

    Kept as a thin shim — warns and returns the f32 per-shard runner with
    the pre-TableSpec ``(hot_in, hot_out, cold_in, cold_out, step) ->
    4-tuple`` signature, for callers that embed it in their own
    ``shard_map``.
    """
    warnings.warn(
        "ops.vocab_sharded_update is deprecated; use ops.step with "
        "vocab-sharded Tables (spec/placement metadata select the "
        "exchange), or _vocab_sharded_run for a raw per-shard runner",
        DeprecationWarning, stacklevel=2)
    run = _vocab_sharded_run(backend, static, placement,
                             axis_name=axis_name, exchange=exchange,
                             spec=TableSpec(vocab_shard=True))

    def run4(hot_in, hot_out, cold_in, cold_out, step):
        return run(hot_in, hot_out, cold_in, cold_out, None, None, step)[:4]

    return run4
