"""Engine API: backend registry, capability descriptors, ``StepInputs``.

FULL-W2V's core design is a host/device contract (paper §3.1, §4.2): the
CPU prepares batches, negatives, and tile schedules; the accelerator
consumes dense arrays. This module is the single seam where that contract
meets backend selection. Every kernel variant — the jnp oracles, the
Pallas kernels, their interpret-mode and window-tiled forms — registers a
:class:`KernelBackend` descriptor declaring what it needs (a host tile
plan?) and what it supports (mesh sharding, §3.1 prefetch, window tiling,
TPU-only compilation). Resolution ("auto", sequential→tiled mapping,
invalid-combination errors) happens once, here, against those descriptors
— instead of string compares scattered across trainer/ops/CLI.

The actual backend implementations register themselves from
``repro.kernels.ops`` at import time; every registry query triggers that
import lazily so callers (CLI, tests) never have to remember to.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, TYPE_CHECKING

import jax

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycles
    from repro.data.batching import Batch


# ---------------------------------------------------------------------------
# StepInputs — the one argument struct every backend update() consumes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepInputs:
    """Device inputs for one training step (a pytree: passes through jit
    and shard_map directly). ``plan_*`` carry the host tile schedule
    (``repro.data.batching.plan_tiles``) and are all-or-none: present for
    the window-tiled backends, ``None`` for the sequential ones.
    ``cold_ids``/``bucket_ids``/``bucket_pos`` carry the vocab-sharding
    exchange plan (``repro.distributed.vocab_placement.plan_exchange``):
    when present, the token/negative/plan arrays are remapped into
    per-shard working-table space, ``bucket_*`` hold the per-owner
    capacity buckets the request-exact ``all_to_all`` exchange routes, and
    the step must run under a mesh session (a vocab-sharded ``ops.step``),
    not a single-replica one. ``round_key`` carries the batch's keyed
    stochastic-rounding key (``kernels.quant.round_key`` — a pure function
    of ``(seed, epoch, batch_index)``) and is attached only when the
    session's :class:`~repro.kernels.tables.TableSpec` stores a table
    below f32. ``static_ctx``/``bags`` carry the workload-frontend
    extensions (DESIGN.md §12): a per-sentence always-in-window extra
    context row (doc2vec PV-DM) and per-position subword bag members
    (fastText-style n-gram bags), both already in table-row space."""
    tokens: jax.Array                       # (S, L) int32
    negs: jax.Array                         # (S, L, N) int32
    lengths: jax.Array                      # (S,) int32
    lr: jax.Array                           # scalar f32
    plan_uniq: Optional[jax.Array] = None     # (S, nt, T*(N+1)) int32
    plan_scatter: Optional[jax.Array] = None  # (S, nt, T*(N+1)) int32
    plan_ucount: Optional[jax.Array] = None   # (S, nt) int32
    plan_strict: Optional[jax.Array] = None   # (S, nt) int32
    cold_ids: Optional[jax.Array] = None      # (n_shards, R) int32, -1 pad
    bucket_ids: Optional[jax.Array] = None    # (n, n, C) int32, -1 pad
    bucket_pos: Optional[jax.Array] = None    # (n, n, C) int32, R pad
    round_key: Optional[jax.Array] = None     # (2,) uint32 threefry key
    static_ctx: Optional[jax.Array] = None    # (S,) int32 doc rows, -1 pad
    bags: Optional[jax.Array] = None          # (S, L, B) int32, -1 pad

    @property
    def has_plan(self) -> bool:
        """Whether this step carries a host tile schedule (tiled family)."""
        return self.plan_uniq is not None

    @property
    def has_vocab_shard(self) -> bool:
        """Whether this step carries a vocab-sharding exchange plan."""
        return self.cold_ids is not None

    @property
    def has_static_ctx(self) -> bool:
        """Whether this step carries per-sentence static context rows."""
        return self.static_ctx is not None

    @property
    def has_bags(self) -> bool:
        """Whether this step carries per-position subword bag members."""
        return self.bags is not None

    @property
    def tile(self) -> int:
        """T — static, derived from the plan shape (M = T*(N+1))."""
        if not self.has_plan:
            return 1
        m = self.negs.shape[-1] + 1
        return self.plan_uniq.shape[-1] // m

    @classmethod
    def from_batch(cls, batch: "Batch", lr) -> "StepInputs":
        """Lift a host :class:`~repro.data.batching.Batch` (numpy) onto the
        device, carrying its tile plan along when one is attached."""
        import jax.numpy as jnp

        kw = {}
        if batch.plan is not None:
            p = batch.plan
            kw = dict(plan_uniq=jnp.asarray(p.uniq),
                      plan_scatter=jnp.asarray(p.scatter),
                      plan_ucount=jnp.asarray(p.ucount),
                      plan_strict=jnp.asarray(p.strict))
        if getattr(batch, "docs", None) is not None:
            kw["static_ctx"] = jnp.asarray(batch.docs)
        if getattr(batch, "bags", None) is not None:
            kw["bags"] = jnp.asarray(batch.bags)
        return cls(tokens=jnp.asarray(batch.tokens),
                   negs=jnp.asarray(batch.negs),
                   lengths=jnp.asarray(batch.lengths),
                   lr=jnp.asarray(lr, jnp.float32), **kw)


jax.tree_util.register_dataclass(
    StepInputs,
    data_fields=["tokens", "negs", "lengths", "lr", "plan_uniq",
                 "plan_scatter", "plan_ucount", "plan_strict", "cold_ids",
                 "bucket_ids", "bucket_pos", "round_key", "static_ctx",
                 "bags"],
    meta_fields=[])


@dataclasses.dataclass(frozen=True)
class KernelStatic:
    """Static (hashable, jit-cache-key) kernel parameters."""
    w_f: int                # fixed context width W_f = ceil(W/2)
    tile: int = 1           # T — windows fused per kernel step
    gemm_windows: int = 0   # G — windows per GEMM group (resolved, not 0)


# ---------------------------------------------------------------------------
# Backend descriptors + registry
# ---------------------------------------------------------------------------

# update(w_in, w_out, step, static) -> (w_in, w_out); traceable (the engine
# wraps it in jit / shard_map)
UpdateFn = Callable[[jax.Array, jax.Array, StepInputs, KernelStatic],
                    Tuple[jax.Array, jax.Array]]

# update_fused(hot_in, hot_out, got_in, got_out, step, static) -> 4-tuple:
# the vocab-sharded working table handed to the kernel *split* — hot
# replica and gathered cold block stay separate HBM buffers and the kernel
# streams rows from whichever side owns them (no concat materialization)
FusedUpdateFn = Callable[
    [jax.Array, jax.Array, jax.Array, jax.Array, StepInputs, KernelStatic],
    Tuple[jax.Array, jax.Array, jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One registered kernel variant and its capability descriptor."""
    name: str
    update: UpdateFn
    description: str = ""
    needs_plan: bool = False          # consumes a host tile schedule
    supports_mesh: bool = True        # usable under shard_map data sharding
    supports_pipeline: bool = False   # §3.1 prefetch (window t+1 DMA overlap)
    supports_tiling: bool = False     # has a window-tiled counterpart
    supports_vocab_shard: bool = False  # runs on the compact working table
                                        # of a vocab-sharded step (§8)
    # storage dtypes the engine's step wrappers can feed this backend
    # (tables.TableSpec dtypes): rows dequantize to f32 at the working-set
    # boundary (VMEM on hardware), the update math is f32 everywhere.
    # Backends missing a dtype still run it under the f32 master-copy
    # fallback (TableSpec.master_copy) — resolve() spells that out.
    supports_dtypes: Tuple[str, ...] = ("float32",)
    # frontend features (DESIGN.md §12) the update() consumes when present
    # on StepInputs: "static_ctx" (doc2vec always-in-window row), "bags"
    # (fastText subword bag members). Backends not declaring a feature
    # must not be handed a step carrying it — resolve() enforces this.
    supports_frontends: Tuple[str, ...] = ()
    requires_tpu: bool = False        # compiles natively only on TPU
    tiled_variant: Optional[str] = None      # name of the tiled counterpart
    interpret_variant: Optional[str] = None  # interpret-mode escape hatch
    update_fused: Optional[FusedUpdateFn] = None  # split-table entry point

    @property
    def supports_fused_gather(self) -> bool:
        """Whether the vocab-sharded step can hand this backend the hot
        replica and the gathered cold rows as separate buffers, fusing the
        cold-row fetch into the kernel's DMA stream instead of paying a
        ``concat(hot, gathered)`` materialization per step (§8)."""
        return self.update_fused is not None


_REGISTRY: Dict[str, KernelBackend] = {}


def register(backend: KernelBackend) -> KernelBackend:
    """Register a kernel backend descriptor; names are unique, first
    registration wins and re-registration raises."""
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def _ensure_registered() -> None:
    # backends self-register on import of ops; lazy so registry never has a
    # module-level dependency back onto the implementations
    if not _REGISTRY:
        import repro.kernels.ops  # noqa: F401  (registers backends)


def get(name: str) -> KernelBackend:
    """Exact-name registry lookup (no "auto"/variant mapping — that is
    :func:`resolve`); unknown names raise with the registered set listed."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))} (or 'auto')") from None


def names() -> List[str]:
    """All registered backend names (stable registration order)."""
    _ensure_registered()
    return list(_REGISTRY)


def cli_choices() -> List[str]:
    """Backend choices for the CLI: 'auto' plus every registered backend."""
    return ["auto"] + names()


def resolve(name: str, *, tiled: bool = False, vocab_shard: bool = False,
            dtypes: Tuple[str, ...] = (),
            frontends: Tuple[str, ...] = (),
            platform: Optional[str] = None) -> KernelBackend:
    """Resolve a backend name against the registry for this step shape.

    * ``"auto"`` picks the fastest native backend for ``platform``
      (default: the running jax backend): Pallas on TPU (pipelined for the
      sequential path), the compiled jnp oracle elsewhere. With
      ``vocab_shard=True`` the TPU sequential pick is plain ``pallas``
      (the pipelined kernel opts out of vocab sharding, see below).
    * A sequential name with ``tiled=True`` maps to its declared
      ``tiled_variant`` (the trainer's T>1 dispatch). ``pallas_pipelined``
      warns on this mapping: the tiled kernel does not implement §3.1
      prefetch, so the request is downgraded — loudly, not silently.
    * ``vocab_shard=True`` additionally requires the resolved backend to
      declare ``supports_vocab_shard`` (it will be handed a compact
      hot+gathered working table instead of the full ``(V, d)`` one).
    * ``dtypes`` (a ``TableSpec.dtypes`` tuple) requires every requested
      storage dtype in the resolved backend's ``supports_dtypes``.
      Callers running the f32 master-copy fallback pass ``()`` — the
      fallback feeds the backend plain f32 tables.
    * ``frontends`` (a workload's required feature set, e.g.
      ``("static_ctx",)``) requires every feature in the resolved
      backend's ``supports_frontends`` — workload steps carry extra
      ``StepInputs`` fields the kernel must consume (DESIGN.md §12).
    * Invalid combinations (a plan-consuming backend without a plan, a
      TPU-only backend off-TPU, a vocab-shard-incapable backend on a
      sharded step, an unknown name) raise ``ValueError`` with the fix
      spelled out.
    """
    _ensure_registered()
    platform = platform or jax.default_backend()
    if name == "auto":
        if platform == "tpu" and not frontends:
            name = ("pallas_tiled" if tiled else
                    "pallas" if vocab_shard else "pallas_pipelined")
        else:
            name = "jnp_tiled" if tiled else "jnp"
    be = get(name)
    if tiled and not be.needs_plan:
        if not be.supports_tiling or be.tiled_variant is None:
            raise ValueError(
                f"backend {be.name!r} has no window-tiled variant; "
                f"set cfg.tile_windows=1 or pick one of: "
                f"{', '.join(n for n in _REGISTRY if _REGISTRY[n].needs_plan)}")
        if be.supports_pipeline:
            import warnings
            warnings.warn(
                f"backend {be.name!r} requests §3.1 prefetch, which the "
                f"window-tiled kernel does not implement; falling back to "
                f"{be.tiled_variant!r} (tiling amortizes DMA latency over T "
                f"windows, subsuming most of the prefetch win)",
                UserWarning, stacklevel=2)
        be = _REGISTRY[be.tiled_variant]
    if not tiled and be.needs_plan:
        raise ValueError(
            f"backend {be.name!r} consumes a host tile schedule but none was "
            f"provided; set cfg.tile_windows > 1 so the batching pipeline "
            f"attaches a plan (repro.data.batching.plan_tiles), or use a "
            f"sequential backend: "
            f"{', '.join(n for n in _REGISTRY if not _REGISTRY[n].needs_plan)}")
    if vocab_shard and not be.supports_vocab_shard:
        capable = ', '.join(n for n in _REGISTRY
                            if _REGISTRY[n].supports_vocab_shard)
        raise ValueError(
            f"backend {be.name!r} does not support vocab-sharded tables "
            f"(it would be handed a compact hot+gathered working table, "
            f"not the full (V, d) one); set cfg.vocab_shard=False or pick "
            f"one of: {capable}")
    missing = [d for d in dtypes if d not in be.supports_dtypes]
    if missing:
        capable = ', '.join(
            n for n in _REGISTRY
            if all(d in _REGISTRY[n].supports_dtypes for d in dtypes)
            and _REGISTRY[n].needs_plan == be.needs_plan) or "<none>"
        raise ValueError(
            f"backend {be.name!r} stores tables only in "
            f"{', '.join(be.supports_dtypes)} but the TableSpec requests "
            f"{', '.join(dtypes)}; pick a capable backend ({capable}) or "
            f"set the f32 master-copy fallback (--tables ...,master=1 / "
            f"TableSpec(master_copy=True)) — tables then dequantize to f32 "
            f"around the unmodified step (correct, but no exchange-byte "
            f"win)")
    missing_fe = [f for f in frontends if f not in be.supports_frontends]
    if missing_fe:
        capable = ', '.join(
            n for n in _REGISTRY
            if all(f in _REGISTRY[n].supports_frontends for f in frontends)
            and _REGISTRY[n].needs_plan == be.needs_plan) or "<none>"
        raise ValueError(
            f"backend {be.name!r} does not consume the frontend feature(s) "
            f"{', '.join(missing_fe)} this workload's steps carry "
            f"(DESIGN.md §12); pick a capable backend ({capable}) or run "
            f"the plain w2v workload")
    if be.requires_tpu and platform != "tpu":
        hint = (f"use {be.interpret_variant!r} (interpret mode: identical "
                f"semantics, correctness-only speed) or "
                if be.interpret_variant else "use ")
        raise ValueError(
            f"backend {be.name!r} compiles natively only on TPU, but this "
            f"process is running on {platform!r}; {hint}"
            f"{'jnp_tiled' if be.needs_plan else 'jnp'!r} (compiled oracle).")
    return be
