"""Pure-jnp oracle for the FULL-W2V kernel.

Implements exactly the schedule of `repro.core.window.schedule`:

  preload positions 0..W_f-1
  for t in 0..len-1:
      q = t + W_f: store evicted position q - R (if any), load q
      process window t (shared-negative GEMM update, pre-window values)
  flush surviving positions in increasing order

The Pallas kernel (`fullw2v.py`) must match this to float tolerance; the
property tests additionally check this oracle against a direct
no-ring-buffer recomputation (`repro.core.baselines.matrix_sgns`) on the
quantities where they must agree.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sgns import window_delta


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def sentence_sgns_ref(
    w_in: jax.Array,      # (V, d) f32 input embeddings
    w_out: jax.Array,     # (V, d) f32 output embeddings
    tokens: jax.Array,    # (L,) int32, padded with anything beyond `length`
    negs: jax.Array,      # (L, N) int32 pre-sampled negatives per window
    length: jax.Array,    # scalar int32 — actual sentence length
    lr: jax.Array,        # scalar f32
    w_f: int,
) -> Tuple[jax.Array, jax.Array]:
    L, N = negs.shape
    V, d = w_in.shape
    r = 2 * w_f + 1
    offsets = jnp.array([o for o in range(-w_f, w_f + 1) if o != 0],
                        dtype=jnp.int32)                      # (K,)

    buf = jnp.zeros((r, d), w_in.dtype)

    # --- preload positions 0..w_f-1 ---
    def preload(q, carry):
        w_in, buf = carry
        valid = q < length
        tok = tokens[jnp.clip(q, 0, L - 1)]
        row = jnp.where(valid, w_in[tok], buf[q % r])
        buf = buf.at[q % r].set(row)
        return (w_in, buf)

    w_in, buf = jax.lax.fori_loop(0, min(w_f, L), preload, (w_in, buf))

    def step(t, carry):
        w_in, w_out, buf = carry
        active = t < length

        # --- evict + load leading edge q = t + w_f ---
        q = t + w_f
        do_load = active & (q < length)
        old = q - r
        do_store = do_load & (old >= 0)
        old_c = jnp.clip(old, 0, L - 1)
        store_idx = tokens[old_c]
        store_val = jnp.where(do_store, buf[old_c % r], w_in[store_idx])
        w_in = w_in.at[store_idx].set(store_val)

        q_c = jnp.clip(q, 0, L - 1)
        load_row = jnp.where(do_load, w_in[tokens[q_c]], buf[q_c % r])
        buf = buf.at[q_c % r].set(load_row)

        # --- window t ---
        p = t + offsets                                       # (K,)
        mask = active & (p >= 0) & (p < length)
        slots = jnp.mod(p, r)
        ctx = buf[slots]                                      # (K, d)
        out_idx = jnp.concatenate([tokens[t][None], negs[t]]) # (N+1,)
        out_rows = w_out[out_idx]
        d_ctx, d_out = window_delta(ctx, out_rows, mask, lr)
        buf = buf.at[slots].add(d_ctx)        # masked rows contribute zeros
        w_out = w_out.at[out_idx].add(jnp.where(active, d_out, 0.0))
        return (w_in, w_out, buf)

    w_in, w_out, buf = jax.lax.fori_loop(0, L, step, (w_in, w_out, buf))

    # --- flush surviving positions length-r .. length-1 (increasing) ---
    def flush(k, carry):
        w_in, buf = carry
        p = length - r + k
        valid = p >= 0
        p_c = jnp.clip(p, 0, L - 1)
        idx = tokens[p_c]
        val = jnp.where(valid, buf[jnp.mod(p_c, r)], w_in[idx])
        w_in = w_in.at[idx].set(val)
        return (w_in, buf)

    w_in, buf = jax.lax.fori_loop(0, r, flush, (w_in, buf))
    return w_in, w_out


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def batch_sgns_ref(
    w_in: jax.Array,      # (V, d)
    w_out: jax.Array,     # (V, d)
    tokens: jax.Array,    # (S, L)
    negs: jax.Array,      # (S, L, N)
    lengths: jax.Array,   # (S,)
    lr: jax.Array,        # scalar
    w_f: int,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (deterministic) pass over a batch of sentences — the same
    order the Pallas grid uses."""

    def body(carry, xs):
        w_in, w_out = carry
        toks, ngs, ln = xs
        w_in, w_out = sentence_sgns_ref(w_in, w_out, toks, ngs, ln, lr, w_f)
        return (w_in, w_out), None

    (w_in, w_out), _ = jax.lax.scan(body, (w_in, w_out),
                                    (tokens, negs, lengths))
    return w_in, w_out
