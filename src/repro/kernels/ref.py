"""Pure-jnp oracle for the FULL-W2V kernel.

Implements exactly the schedule of `repro.core.window.schedule`:

  preload positions 0..W_f-1
  for t in 0..len-1:
      q = t + W_f: store evicted position q - R (if any), load q
      process window t (shared-negative GEMM update, pre-window values)
  flush surviving positions in increasing order

The Pallas kernel (`fullw2v.py`) must match this to float tolerance; the
property tests additionally check this oracle against a direct
no-ring-buffer recomputation (`repro.core.baselines.matrix_sgns`) on the
quantities where they must agree.

`batch_sgns_tiled_ref` mirrors the *tiled* kernel (`_kernel_tiled`,
DESIGN.md §4): T windows per step over a ``T + 2*W_f`` ring, fused
pre-tile-value updates for collision-free tiles, sequential replay for
``strict`` tiles. It consumes the same host schedule
(`repro.data.batching.plan_tiles`) as the kernel, so interpret-mode tests
can diff the two implementations directly.

These oracles are registered with the engine API as the ``jnp`` and
``jnp_tiled`` backends (``kernels.ops`` / ``kernels.registry``) — being
fully compiled, they are also what ``backend="auto"`` resolves to off-TPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sgns import stable_sigmoid, window_delta


def _position_row(t_c, tokens, bags, w_in):
    """Input-side row for position ``t_c``: the token's embedding, or — for
    subword workloads — the masked sum over the position's bag members
    (word row + hashed n-gram bucket rows, -1 padded)."""
    if bags is None:
        return w_in[tokens[t_c]]
    V = w_in.shape[0]
    mem = bags[t_c]                                      # (B,)
    ok = mem >= 0
    rows = w_in[jnp.clip(mem, 0, V - 1)]                 # (B, d)
    return jnp.where(ok[:, None], rows, 0.0).sum(0)


def _bag_scatter(w_in, bags, t_c, do_store, delta):
    """Delta store for a bag position: every valid member receives the full
    accumulated gradient (fastText sum-gradient; duplicate members — n-gram
    hash collisions within one word — accumulate, matching the bag sum)."""
    V = w_in.shape[0]
    mem = bags[t_c]                                      # (B,)
    ok = (mem >= 0) & do_store
    mem_c = jnp.clip(mem, 0, V - 1)
    return w_in.at[mem_c].add(jnp.where(ok[:, None], delta[None, :], 0.0))


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def sentence_sgns_ref(
    w_in: jax.Array,      # (V, d) f32 input embeddings
    w_out: jax.Array,     # (V, d) f32 output embeddings
    tokens: jax.Array,    # (L,) int32, padded with anything beyond `length`
    negs: jax.Array,      # (L, N) int32 pre-sampled negatives per window
    length: jax.Array,    # scalar int32 — actual sentence length
    lr: jax.Array,        # scalar f32
    w_f: int,
    static_id=None,       # scalar int32 table row (-1 = none): doc2vec row
    bags=None,            # (L, B) int32 member rows, -1 padded: subword bags
) -> Tuple[jax.Array, jax.Array]:
    """One sentence of the sequential FULL-W2V schedule: ring-buffer
    context reuse (§3.2) + shared-negative window GEMMs (§3.1), exactly as
    the module docstring lays out. The oracle the Pallas kernels are
    tested against.

    Frontend extensions (DESIGN.md §12): ``static_id`` appends an
    always-in-window extra context row (PV-DM document vector, loaded once
    per sentence, written back once); ``bags`` replaces every position's
    input row with a masked bag sum and turns the ring's write-backs into
    delta scatter-adds over the bag members (via a ``buf0`` load mirror)."""
    L, N = negs.shape
    V, d = w_in.shape
    r = 2 * w_f + 1
    offsets = jnp.array([o for o in range(-w_f, w_f + 1) if o != 0],
                        dtype=jnp.int32)                      # (K,)

    buf = jnp.zeros((r, d), w_in.dtype)
    # load-time mirror: bag stores write back buf - buf0 (the accumulated
    # gradient) to every member instead of overwriting a single row
    buf0 = jnp.zeros((r, d), w_in.dtype) if bags is not None else None

    has_doc = (static_id >= 0) if static_id is not None else None
    sid_c = jnp.clip(static_id, 0, V - 1) if static_id is not None else None
    doc0 = (jnp.where(has_doc, w_in[sid_c], 0.0)
            if static_id is not None else None)
    doc_val = doc0

    # --- preload positions 0..w_f-1 ---
    def preload(q, carry):
        w_in, buf, buf0 = carry
        valid = q < length
        q_c = jnp.clip(q, 0, L - 1)
        row = jnp.where(valid, _position_row(q_c, tokens, bags, w_in),
                        buf[q % r])
        buf = buf.at[q % r].set(row)
        if bags is not None:
            # mirror only real loads: a clipped q aliases a live slot
            buf0 = buf0.at[q % r].set(jnp.where(valid, row, buf0[q % r]))
        return (w_in, buf, buf0)

    w_in, buf, buf0 = jax.lax.fori_loop(0, min(w_f, L), preload,
                                        (w_in, buf, buf0))

    def step(t, carry):
        w_in, w_out, buf, buf0, doc_val = carry
        active = t < length

        # --- evict + load leading edge q = t + w_f ---
        q = t + w_f
        do_load = active & (q < length)
        old = q - r
        do_store = do_load & (old >= 0)
        old_c = jnp.clip(old, 0, L - 1)
        if bags is None:
            store_idx = tokens[old_c]
            store_val = jnp.where(do_store, buf[old_c % r], w_in[store_idx])
            w_in = w_in.at[store_idx].set(store_val)
        else:
            slot = old_c % r
            delta = jnp.where(do_store, buf[slot] - buf0[slot], 0.0)
            w_in = _bag_scatter(w_in, bags, old_c, do_store, delta)

        q_c = jnp.clip(q, 0, L - 1)
        load_row = jnp.where(do_load, _position_row(q_c, tokens, bags, w_in),
                             buf[q_c % r])
        buf = buf.at[q_c % r].set(load_row)
        if bags is not None:
            buf0 = buf0.at[q_c % r].set(
                jnp.where(do_load, load_row, buf0[q_c % r]))

        # --- window t ---
        p = t + offsets                                       # (K,)
        mask = active & (p >= 0) & (p < length)
        slots = jnp.mod(p, r)
        ctx = buf[slots]                                      # (K, d)
        out_idx = jnp.concatenate([tokens[t][None], negs[t]]) # (N+1,)
        out_rows = w_out[out_idx]
        if static_id is not None:
            # doc row rides as a (K+1)-th context row in every window
            ctx = jnp.concatenate([ctx, doc_val[None]], axis=0)
            mask = jnp.concatenate([mask, (active & has_doc)[None]])
        d_ctx, d_out = window_delta(ctx, out_rows, mask, lr)
        if static_id is not None:
            doc_val = doc_val + d_ctx[-1]
            d_ctx = d_ctx[:-1]
        buf = buf.at[slots].add(d_ctx)        # masked rows contribute zeros
        w_out = w_out.at[out_idx].add(jnp.where(active, d_out, 0.0))
        return (w_in, w_out, buf, buf0, doc_val)

    w_in, w_out, buf, buf0, doc_val = jax.lax.fori_loop(
        0, L, step, (w_in, w_out, buf, buf0, doc_val))

    # --- flush surviving positions length-r .. length-1 (increasing) ---
    def flush(k, carry):
        w_in, buf, buf0 = carry
        p = length - r + k
        valid = p >= 0
        p_c = jnp.clip(p, 0, L - 1)
        if bags is None:
            idx = tokens[p_c]
            val = jnp.where(valid, buf[jnp.mod(p_c, r)], w_in[idx])
            w_in = w_in.at[idx].set(val)
        else:
            slot = jnp.mod(p_c, r)
            delta = jnp.where(valid, buf[slot] - buf0[slot], 0.0)
            w_in = _bag_scatter(w_in, bags, p_c, valid, delta)
        return (w_in, buf, buf0)

    w_in, buf, buf0 = jax.lax.fori_loop(0, r, flush, (w_in, buf, buf0))
    if static_id is not None:
        w_in = w_in.at[sid_c].add(jnp.where(has_doc, doc_val - doc0, 0.0))
    return w_in, w_out


@functools.partial(jax.jit, static_argnames=("w_f",), donate_argnums=(0, 1))
def batch_sgns_ref(
    w_in: jax.Array,      # (V, d)
    w_out: jax.Array,     # (V, d)
    tokens: jax.Array,    # (S, L)
    negs: jax.Array,      # (S, L, N)
    lengths: jax.Array,   # (S,)
    lr: jax.Array,        # scalar
    w_f: int,
    static_ids=None,      # (S,) int32 doc rows per sentence, -1 = none
    bags=None,            # (S, L, B) int32 bag members, -1 padded
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (deterministic) pass over a batch of sentences — the same
    order the Pallas grid uses."""

    def body(carry, xs):
        w_in, w_out = carry
        toks, ngs, ln, sid, bg = xs
        w_in, w_out = sentence_sgns_ref(w_in, w_out, toks, ngs, ln, lr, w_f,
                                        static_id=sid, bags=bg)
        return (w_in, w_out), None

    (w_in, w_out), _ = jax.lax.scan(body, (w_in, w_out),
                                    (tokens, negs, lengths, static_ids,
                                     bags))
    return w_in, w_out


# ---------------------------------------------------------------------------
# Tiled oracle (mirrors `_kernel_tiled`, DESIGN.md §4)
# ---------------------------------------------------------------------------

def _sentence_sgns_tiled(w_in, w_out, tokens, negs, length, lr,
                         uniq, scatter, ucount, strict,
                         *, w_f: int, tile: int, gemm_windows: int,
                         static_id=None, bags=None):
    """One sentence of the tiled schedule. Shapes: tokens (L,), negs (L, N),
    uniq/scatter (nt, T*(N+1)), ucount/strict (nt,). ``static_id``/``bags``
    mirror `sentence_sgns_ref`'s frontend extensions (DESIGN.md §12)."""
    G = gemm_windows
    L, N = negs.shape
    V, d = w_in.shape
    m = N + 1
    k = 2 * w_f
    rt = tile + 2 * w_f
    nt = uniq.shape[0]
    M = tile * m
    offsets = jnp.array([o for o in range(-w_f, w_f + 1) if o != 0],
                        dtype=jnp.int32)                      # (k,)

    buf = jnp.zeros((rt, d), w_in.dtype)
    buf0 = jnp.zeros((rt, d), w_in.dtype) if bags is not None else None
    r_seq = 2 * w_f + 1            # sequential store distance

    has_doc = (static_id >= 0) if static_id is not None else None
    sid_c = jnp.clip(static_id, 0, V - 1) if static_id is not None else None
    doc0 = (jnp.where(has_doc, w_in[sid_c], 0.0)
            if static_id is not None else None)
    doc_val = doc0

    # --- preload positions 0..w_f-1 ---
    def preload(q, carry):
        w_in, buf, buf0 = carry
        valid = q < length
        q_c = jnp.clip(q, 0, L - 1)
        row = jnp.where(valid, _position_row(q_c, tokens, bags, w_in),
                        buf[q % rt])
        buf = buf.at[q % rt].set(row)
        if bags is not None:
            # mirror only real loads: a clipped q aliases a live slot
            buf0 = buf0.at[q % rt].set(jnp.where(valid, row, buf0[q % rt]))
        return (w_in, buf, buf0)

    w_in, buf, buf0 = jax.lax.fori_loop(0, min(w_f, L), preload,
                                        (w_in, buf, buf0))

    # ring advance pieces — slot modulus rt (rows stay resident for the
    # whole tile) but the *store schedule* is the sequential kernel's
    # (store the r-distance evictee once its windows are complete)
    def _store(t, act, w_in, buf, buf0):
        q = t + w_f
        old = q - r_seq
        do_store = act & (q < length) & (old >= 0)
        old_c = jnp.clip(old, 0, L - 1)
        if bags is None:
            store_idx = tokens[old_c]
            store_val = jnp.where(do_store, buf[old_c % rt],
                                  w_in[store_idx])
            return w_in.at[store_idx].set(store_val)
        slot = old_c % rt
        delta = jnp.where(do_store, buf[slot] - buf0[slot], 0.0)
        return _bag_scatter(w_in, bags, old_c, do_store, delta)

    def _load(t, act, w_in, buf, buf0):
        q = t + w_f
        do_load = act & (q < length)
        q_c = jnp.clip(q, 0, L - 1)
        load_row = jnp.where(do_load,
                             _position_row(q_c, tokens, bags, w_in),
                             buf[q_c % rt])
        buf = buf.at[q_c % rt].set(load_row)
        if bags is not None:
            buf0 = buf0.at[q_c % rt].set(
                jnp.where(do_load, load_row, buf0[q_c % rt]))
        return buf, buf0

    def tile_step(i, carry):
        w_in, w_out, buf, buf0, doc_val = carry
        t0 = i * tile
        active = t0 < length

        def strict_tile(carry):
            """Bit-exact sequential replay (same math and ring advance
            order as `sentence_sgns_ref`)."""
            w_in, w_out, buf, buf0, doc_val = carry
            for w in range(tile):
                t = t0 + w
                act = active & (t < length)
                w_in = _store(t, act, w_in, buf, buf0)
                buf, buf0 = _load(t, act, w_in, buf, buf0)
                t_c = jnp.clip(t, 0, L - 1)
                p = t + offsets
                mask = act & (p >= 0) & (p < length)
                slots = jnp.mod(jnp.clip(p, 0, L - 1), rt)
                ctx = buf[slots]
                out_idx = jnp.concatenate([tokens[t_c][None], negs[t_c]])
                out_rows = w_out[out_idx]
                if static_id is not None:
                    ctx = jnp.concatenate([ctx, doc_val[None]], axis=0)
                    mask = jnp.concatenate([mask, (act & has_doc)[None]])
                d_ctx, d_out = window_delta(ctx, out_rows, mask, lr)
                if static_id is not None:
                    doc_val = doc_val + d_ctx[-1]
                    d_ctx = d_ctx[:-1]
                buf = buf.at[slots].add(d_ctx)
                w_out = w_out.at[out_idx].add(jnp.where(act, d_out, 0.0))
            return (w_in, w_out, buf, buf0, doc_val)

        def fused_tile(carry):
            """GEMM groups of G windows over the tile's deduplicated rows:
            the rows are read/written to the table once per tile, while
            deltas become visible between groups (mirrors `_kernel_tiled`'s
            bounded-staleness fused path)."""
            w_in, w_out, buf, buf0, doc_val = carry
            u_vals = w_out[uniq[i]]                            # (M, d)
            u_orig = u_vals

            for b in range((tile + G - 1) // G):
                w0 = b * G
                wn = min(G, tile - w0)
                base = t0 + w0
                g_act = active & (base < length)
                # group ring advance: window 0 store-then-load (sequential
                # order), remaining windows load-only here / store after
                # the GEMM once their context updates have landed
                w_in = _store(base, g_act, w_in, buf, buf0)
                for w in range(wn):
                    buf, buf0 = _load(base + w, g_act, w_in, buf, buf0)
                centers = base + jnp.arange(wn, dtype=jnp.int32)
                p = centers[:, None] + offsets[None, :]        # (wn, k)
                p_flat = p.reshape(-1)
                p_ok = (p_flat >= 0) & (p_flat < length)
                slots = jnp.mod(jnp.clip(p_flat, 0, L - 1), rt)
                ctx = jnp.where(p_ok[:, None], buf[slots], 0.0)

                sc = jax.lax.dynamic_slice_in_dim(scatter[i], w0 * m,
                                                  wn * m)
                exp = u_vals[sc]                               # (wn*m, d)

                win_r = jnp.arange(wn * k, dtype=jnp.int32) // k
                win_c = jnp.arange(wn * m, dtype=jnp.int32) // m
                row_valid = active & p_ok & (base + win_r < length)
                col_valid = active & (base + win_c < length)
                if static_id is not None:
                    # one doc row per window of the group, appended after
                    # the position rows (group-start value for all windows
                    # of the group — same bounded staleness as u_vals)
                    wins = jnp.arange(wn, dtype=jnp.int32)
                    ctx = jnp.concatenate(
                        [ctx, jnp.broadcast_to(doc_val, (wn, d))], axis=0)
                    win_r = jnp.concatenate([win_r, wins])
                    row_valid = jnp.concatenate(
                        [row_valid,
                         g_act & has_doc & (base + wins < length)])
                label = (jnp.arange(wn * m, dtype=jnp.int32) % m
                         == 0).astype(ctx.dtype)
                mask = (row_valid[:, None] & col_valid[None, :]
                        & (win_r[:, None] == win_c[None, :]))

                corr = ctx @ exp.T                         # (rows, wn*m)
                g = lr * (label[None, :] - stable_sigmoid(corr))
                g = jnp.where(mask, g, 0.0)
                d_ctx = g @ exp                            # (rows, d)
                d_out = g.T @ ctx                          # (wn*m, d)

                if static_id is not None:
                    doc_val = doc_val + d_ctx[wn * k:].sum(0)
                    d_ctx = d_ctx[:wn * k]
                buf = buf.at[slots].add(d_ctx)   # repeats accumulate
                u_vals = u_vals.at[sc].add(d_out)

                for w in range(1, wn):           # deferred group stores
                    w_in = _store(base + w, g_act, w_in, buf, buf0)

            w_out = w_out.at[uniq[i]].add(u_vals - u_orig)
            return (w_in, w_out, buf, buf0, doc_val)

        return jax.lax.cond(strict[i] != 0, strict_tile, fused_tile,
                            (w_in, w_out, buf, buf0, doc_val))

    w_in, w_out, buf, buf0, doc_val = jax.lax.fori_loop(
        0, nt, tile_step, (w_in, w_out, buf, buf0, doc_val))

    # --- flush surviving positions length-r_seq .. length-1 (increasing;
    # the r-distance store schedule leaves the same survivors as the
    # sequential kernel) ---
    def flush(kk, carry):
        w_in, buf, buf0 = carry
        p = length - r_seq + kk
        valid = p >= 0
        p_c = jnp.clip(p, 0, L - 1)
        if bags is None:
            idx = tokens[p_c]
            val = jnp.where(valid, buf[jnp.mod(p_c, rt)], w_in[idx])
            w_in = w_in.at[idx].set(val)
        else:
            slot = jnp.mod(p_c, rt)
            delta = jnp.where(valid, buf[slot] - buf0[slot], 0.0)
            w_in = _bag_scatter(w_in, bags, p_c, valid, delta)
        return (w_in, buf, buf0)

    w_in, buf, buf0 = jax.lax.fori_loop(0, r_seq, flush, (w_in, buf, buf0))
    if static_id is not None:
        w_in = w_in.at[sid_c].add(jnp.where(has_doc, doc_val - doc0, 0.0))
    return w_in, w_out


@functools.partial(jax.jit, static_argnames=("w_f", "tile", "gemm_windows"),
                   donate_argnums=(0, 1))
def batch_sgns_tiled_ref(
    w_in: jax.Array,      # (V, d)
    w_out: jax.Array,     # (V, d)
    tokens: jax.Array,    # (S, L)
    negs: jax.Array,      # (S, L, N)
    lengths: jax.Array,   # (S,)
    lr: jax.Array,        # scalar
    w_f: int,
    tile: int,
    uniq: jax.Array,      # (S, nt, T*(N+1)) — from data.batching.plan_tiles
    scatter: jax.Array,   # (S, nt, T*(N+1))
    ucount: jax.Array,    # (S, nt)
    strict: jax.Array,    # (S, nt)
    gemm_windows: int = 0,   # windows per GEMM group; 0 -> min(tile, 4)
    static_ids=None,      # (S,) int32 doc rows per sentence, -1 = none
    bags=None,            # (S, L, B) int32 bag members, -1 padded
) -> Tuple[jax.Array, jax.Array]:
    """Sequential pass over a batch with the tiled (T windows per step)
    semantics — the oracle for `fullw2v.fullw2v_pallas_tiled`."""
    from repro.configs.w2v import resolve_gemm_windows
    G = resolve_gemm_windows(tile, gemm_windows)

    def body(carry, xs):
        w_in, w_out = carry
        toks, ngs, ln, uq, sc, uc, st, sid, bg = xs
        w_in, w_out = _sentence_sgns_tiled(w_in, w_out, toks, ngs, ln, lr,
                                           uq, sc, uc, st,
                                           w_f=w_f, tile=tile,
                                           gemm_windows=G,
                                           static_id=sid, bags=bg)
        return (w_in, w_out), None

    (w_in, w_out), _ = jax.lax.scan(
        body, (w_in, w_out),
        (tokens, negs, lengths, uniq, scatter, ucount, strict,
         static_ids, bags))
    return w_in, w_out
