"""Precision-typed embedding tables: ``TableSpec`` + the ``Tables`` pytree.

One spec describes how the embedding tables are *stored* — per-table
dtype, hot/cold placement, exchange flavor — and the whole engine reads
it from here: ``ops.step`` resolves replica-vs-sharded dispatch and the
mixed-precision wrappers from the spec, the trainer allocates and
checkpoints storage in spec dtypes, ``serve`` restores them natively, and
the CLI constructs one from ``--tables hot=bf16:frac=0.1,cold=int8``
instead of scattering precision/placement knobs across flags
(DESIGN.md §11).

``Tables`` is the registered pytree that carries the actual arrays
through jit/shard_map: full (replicated) tables in ``w_in``/``w_out``, or
the replicated hot head there plus the striped cold tail in
``cold_in``/``cold_out`` with per-row int8 scales colocated in
``scale_in``/``scale_out`` (split and striped by the same
``VocabPlacement`` row permutation as the cold rows themselves). The spec
and placement ride along as static (hashable) metadata, so a jitted step
retraces exactly when the storage format changes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from repro.kernels.quant import STORAGE_DTYPES

_HOT_DTYPES = ("float32", "bfloat16")
_ALIASES = {"f32": "float32", "fp32": "float32", "float32": "float32",
            "bf16": "bfloat16", "bfloat16": "bfloat16",
            "int8": "int8", "i8": "int8"}


def _canon_dtype(name: str, *, hot: bool) -> str:
    dt = _ALIASES.get(name.strip().lower())
    allowed = _HOT_DTYPES if hot else STORAGE_DTYPES
    if dt is None or dt not in allowed:
        which = "hot" if hot else "cold"
        raise ValueError(
            f"unsupported {which}-table dtype {name!r}; choose from "
            f"{', '.join(allowed)} (int8 rows need per-row scales, which "
            f"only the striped cold tail carries)")
    return dt


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """How the embedding tables are stored and placed (static, hashable).

    ``hot_dtype`` covers the replicated tables — the full ``(V, d)`` pair
    of a replicated session, or the Zipf-hot head of a sharded one.
    ``cold_dtype`` covers the striped cold tail and therefore requires
    ``vocab_shard`` (int8 additionally carries per-row scales colocated
    with the cold shards). ``master_copy`` opts into the f32 master-copy
    fallback for backends whose kernels can't consume the storage dtype:
    tables dequantize to f32 around the *unmodified* f32 step and
    re-encode stochastically after — correct everywhere, but it forfeits
    the exchange-byte and working-set wins (the quantized form then only
    pays off in HBM capacity and checkpoints).
    """
    hot_dtype: str = "float32"
    cold_dtype: str = "float32"
    hot_frac: float = 0.0
    vocab_shard: bool = False
    exchange: str = "exact"
    master_copy: bool = False
    shards: int = 0        # CLI device-count hint; 0 = mesh/legacy flag

    def __post_init__(self):
        """Validate dtype/placement/exchange combinations eagerly."""
        if self.hot_dtype not in _HOT_DTYPES:
            raise ValueError(
                f"hot_dtype {self.hot_dtype!r} not in {_HOT_DTYPES}")
        if self.cold_dtype not in STORAGE_DTYPES:
            raise ValueError(
                f"cold_dtype {self.cold_dtype!r} not in {STORAGE_DTYPES}")
        if self.exchange not in ("exact", "dense"):
            raise ValueError(
                f"exchange must be 'exact' or 'dense', got {self.exchange!r}")
        if self.cold_dtype != "float32" and not self.vocab_shard:
            raise ValueError(
                f"cold_dtype={self.cold_dtype!r} requires vocab_shard=True: "
                f"the cold tail (and its per-row scales) only exists under "
                f"a vocab-sharded placement — pass shards in --tables "
                f"(e.g. 'cold=int8,shards=4') or set cfg.vocab_shard")

    # -- derived views -------------------------------------------------------
    @property
    def is_mixed(self) -> bool:
        """Any table stored below f32 (round keys + requant paths on)."""
        return self.hot_dtype != "float32" or self.cold_dtype != "float32"

    @property
    def dtypes(self) -> Tuple[str, ...]:
        """Distinct storage dtypes, for registry capability resolution."""
        out = [self.hot_dtype]
        if self.vocab_shard and self.cold_dtype not in out:
            out.append(self.cold_dtype)
        return tuple(out)

    @property
    def needs_scales(self) -> bool:
        """Whether per-row int8 scales ride with the cold shards."""
        return self.vocab_shard and self.cold_dtype == "int8"

    # -- checkpoint metadata -------------------------------------------------
    def to_extra(self) -> Dict:
        """Checkpoint-manifest metadata (see ``from_extra``)."""
        return {"hot_dtype": self.hot_dtype, "cold_dtype": self.cold_dtype,
                "hot_frac": self.hot_frac, "vocab_shard": self.vocab_shard,
                "exchange": self.exchange, "master_copy": self.master_copy}

    @classmethod
    def from_extra(cls, extra: Dict) -> "TableSpec":
        """Rebuild the writing run's spec from checkpoint metadata
        (missing keys default to f32 — legacy checkpoints)."""
        return cls(hot_dtype=str(extra.get("hot_dtype", "float32")),
                   cold_dtype=str(extra.get("cold_dtype", "float32")),
                   hot_frac=float(extra.get("hot_frac", 0.0)),
                   vocab_shard=bool(extra.get("vocab_shard", False)),
                   exchange=str(extra.get("exchange", "exact")),
                   master_copy=bool(extra.get("master_copy", False)))


def parse(spec: str, *, vocab_shard: bool = False,
          hot_frac: float = 0.0) -> TableSpec:
    """Parse the ``--tables`` surface into a :class:`TableSpec`.

    Grammar: comma-separated clauses, each ``key=value`` with optional
    colon-joined sub-options — e.g. ``hot=bf16:frac=0.1,cold=int8``,
    ``cold=int8,shards=4,exchange=dense``, ``hot=bf16:master=1``.
    Recognized clauses: ``hot=<f32|bf16>[:frac=F][:master=0|1]``,
    ``cold=<f32|bf16|int8>`` (implies vocab sharding), ``shards=N``,
    ``exchange=<exact|dense>``, ``master=0|1``. ``vocab_shard`` /
    ``hot_frac`` seed the defaults from the legacy config knobs so
    ``--vocab-shard``/``--hot-vocab-frac`` keep working underneath.
    """
    kw = dict(vocab_shard=vocab_shard, hot_frac=hot_frac)
    for clause in filter(None, (c.strip() for c in spec.split(","))):
        key, sep, rest = clause.partition("=")
        key = key.strip().lower()
        if not sep:
            raise ValueError(f"--tables clause {clause!r} is not key=value "
                             f"(expected e.g. hot=bf16:frac=0.1,cold=int8)")
        value, *opts = rest.split(":")
        if key == "hot":
            kw["hot_dtype"] = _canon_dtype(value, hot=True)
        elif key == "cold":
            kw["cold_dtype"] = _canon_dtype(value, hot=False)
            kw["vocab_shard"] = True
        elif key == "shards":
            kw["shards"] = int(value)
            kw["vocab_shard"] = kw["shards"] >= 1
        elif key == "exchange":
            kw["exchange"] = value.strip().lower()
        elif key == "master":
            kw["master_copy"] = value.strip() not in ("0", "false", "")
        else:
            raise ValueError(
                f"unknown --tables clause {key!r}; recognized: hot, cold, "
                f"shards, exchange, master")
        for opt in opts:
            okey, _, oval = opt.partition("=")
            okey = okey.strip().lower()
            if key == "hot" and okey == "frac":
                kw["hot_frac"] = float(oval)
            elif okey == "master":
                kw["master_copy"] = oval.strip() not in ("0", "false", "")
            else:
                raise ValueError(
                    f"unknown --tables sub-option {opt!r} on {key}= "
                    f"(recognized: frac= on hot=, master=)")
    return TableSpec(**kw)


def from_config(cfg) -> TableSpec:
    """The session's TableSpec: ``cfg.tables`` when set (legacy
    ``vocab_shard``/``hot_vocab_frac`` knobs seed its defaults), else a
    pure-f32 spec derived from the legacy knobs."""
    if getattr(cfg, "tables", ""):
        return parse(cfg.tables, vocab_shard=cfg.vocab_shard,
                     hot_frac=cfg.hot_vocab_frac)
    return TableSpec(vocab_shard=cfg.vocab_shard,
                     hot_frac=cfg.hot_vocab_frac)


@dataclasses.dataclass
class Tables:
    """The table arrays one engine step consumes and returns (a pytree).

    Replicated sessions populate ``w_in``/``w_out`` with the full
    ``(V, d)`` tables (stored in ``spec.hot_dtype``). Vocab-sharded
    sessions put the replicated hot head there instead, the striped
    ``(cold_pad, d)`` tail in ``cold_in``/``cold_out`` (stored in
    ``spec.cold_dtype``), and — int8 only — the per-row scales in
    ``scale_in``/``scale_out`` (f32 ``(cold_pad,)``, row-sharded exactly
    like the cold tables). ``spec`` and ``placement`` are static metadata:
    part of the jit cache key, invisible to tracing.
    """
    w_in: jax.Array
    w_out: jax.Array
    cold_in: Optional[jax.Array] = None
    cold_out: Optional[jax.Array] = None
    scale_in: Optional[jax.Array] = None
    scale_out: Optional[jax.Array] = None
    spec: TableSpec = TableSpec()
    placement: Optional[object] = None   # VocabPlacement (frozen, hashable)


jax.tree_util.register_dataclass(
    Tables,
    data_fields=["w_in", "w_out", "cold_in", "cold_out",
                 "scale_in", "scale_out"],
    meta_fields=["spec", "placement"])
