"""FULL-W2V Pallas TPU kernel.

The paper's two mechanisms, mapped to TPU (DESIGN.md §2):

* *Lifetime reuse of context words* (§3.2): a VMEM scratch ring buffer of
  ``R = 2*W_f + 1`` embedding rows mirrors the sliding window. Each context
  row is DMA'd HBM→VMEM once when it enters the window, accumulates all its
  updates in VMEM, and is DMA'd back exactly once when it leaves — removing
  2W_f/(2W_f+1) of context-row HBM traffic.

* *Independence of negative samples* (§3.1): the N+1 output rows of a window
  (target + shared negatives) are DMA'd into a VMEM block, used for every
  pairing of the window from that block (the GPU's register caching), and
  written back once per window. Because all pairings commute, the window
  update is expressed as two tiny GEMMs over data already resident in VMEM —
  the MXU-native analogue of the paper's per-negative register loop.

Grid = one step per sentence; the TPU grid is sequential per core, so strict
context-window ordering (required for convergence, paper §3.1) holds by
construction, and batch-level parallelism comes from data parallelism across
cores/chips (Hogwild, as in the paper).

Embedding tables stay in HBM (``memory_space=ANY``); rows move via explicit
``make_async_copy`` — the TPU spelling of the paper's explicit caching.

PRECONDITION (enforced by the host batching pipeline, `repro.data.negatives`,
exactly as the paper performs negative selection on the CPU): within one
window the N negatives are distinct from each other and from the target.
Under this invariant the kernel is bit-identical to `kernels.ref`; with
duplicates the kernel's per-row write-back is last-write-wins while the
oracle scatter-adds (the GPU original has the same benign race).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128     # TPU lane width; embedding dim must be a multiple
SUBLANE = 8    # f32 sublane tile


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(
    # --- scalar/SMEM inputs (per sentence block) ---
    tokens_ref,    # (1, L) int32  SMEM
    negs_ref,      # (1, L, N) int32 SMEM
    length_ref,    # (1,) int32 SMEM
    lr_ref,        # (1,) f32 SMEM
    # --- HBM (ANY) inputs, aliased to outputs ---
    w_in_hbm,      # (V, d)
    w_out_hbm,     # (V, d)
    # --- outputs (aliased) ---
    w_in_out,      # (V, d)
    w_out_out,     # (V, d)
    # --- scratch ---
    ring,          # (R_pad, d) f32 VMEM — context-row ring buffer
    ctx_blk,       # (K_pad, d) f32 VMEM — gathered window context rows
    out_blk,       # (M_pad, d) f32 VMEM — target + negative output rows
    sem,           # DMA semaphore
    *,
    w_f: int,
    n_neg: int,
):
    """See module docstring; `_kernel_pipelined` adds §3.1-style prefetch."""
    L = tokens_ref.shape[1]
    d = w_in_hbm.shape[1]
    r = 2 * w_f + 1
    k = 2 * w_f                      # context slots per window
    m = n_neg + 1                    # output rows per window
    k_pad = ctx_blk.shape[0]
    m_pad = out_blk.shape[0]
    length = length_ref[0]
    lr = lr_ref[0]

    def copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def load_ring(q):
        """HBM w_in row tokens[q] -> ring slot q % r."""
        tok = tokens_ref[0, q]
        copy(w_in_out.at[pl.ds(tok, 1)], ring.at[pl.ds(q % r, 1)])

    def store_ring(p):
        """ring slot p % r -> HBM w_in row tokens[p] (write-through output)."""
        tok = tokens_ref[0, p]
        copy(ring.at[pl.ds(p % r, 1)], w_in_out.at[pl.ds(tok, 1)])

    # --- preload positions 0..w_f-1 ---
    def preload(q, _):
        @pl.when(q < length)
        def _():
            load_ring(q)
        return 0

    jax.lax.fori_loop(0, min(w_f, L), preload, 0, unroll=True)

    # --- main sliding-window loop ---
    def step(t, _):
        # evict + load leading edge
        q = t + w_f

        @pl.when(q < length)
        def _():
            @pl.when(q - r >= 0)
            def _():
                store_ring(q - r)
            load_ring(q)

        # ---- gather context rows (from VMEM ring — no HBM traffic) ----
        offs = [o for o in range(-w_f, w_f + 1) if o != 0]
        for j, off in enumerate(offs):
            p = t + off
            valid = jnp.logical_and(p >= 0, p < length)
            slot = jnp.clip(p, 0, L - 1) % r
            row = ring[pl.ds(slot, 1), :]
            ctx_blk[pl.ds(j, 1), :] = jnp.where(valid, row, 0.0)
        if k_pad > k:
            ctx_blk[pl.ds(k, k_pad - k), :] = jnp.zeros((k_pad - k, d),
                                                        ctx_blk.dtype)

        # ---- fetch output rows: target + shared negatives (paper §3.1) ----
        tgt = tokens_ref[0, t]
        copy(w_out_out.at[pl.ds(tgt, 1)], out_blk.at[pl.ds(0, 1)])
        for j in range(n_neg):
            neg = negs_ref[0, t, j]
            copy(w_out_out.at[pl.ds(neg, 1)], out_blk.at[pl.ds(1 + j, 1)])
        if m_pad > m:
            out_blk[pl.ds(m, m_pad - m), :] = jnp.zeros((m_pad - m, d),
                                                        out_blk.dtype)

        # ---- the window update: two tiny GEMMs on VMEM-resident data ----
        ctx = ctx_blk[...]                         # (k_pad, d)
        out_rows = out_blk[...]                    # (m_pad, d)
        corr = jax.lax.dot_general(
            ctx, out_rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)    # (k_pad, m_pad)
        # stable sigmoid, same formula as core.sgns.stable_sigmoid
        f = jnp.where(corr >= 0,
                      1.0 / (1.0 + jnp.exp(-corr)),
                      jnp.exp(corr) / (1.0 + jnp.exp(corr)))
        label = (jax.lax.broadcasted_iota(jnp.int32, (k_pad, m_pad), 1)
                 == 0).astype(jnp.float32)
        g = lr * (label - f)
        # mask invalid context rows and padded output columns
        # rebuild the static offset list with iota (no captured constants):
        # j < w_f -> j - w_f;  j >= w_f -> j - w_f + 1 (skipping offset 0)
        ji = jax.lax.iota(jnp.int32, k_pad)
        offs_arr = jnp.where(ji < w_f, ji - w_f, ji - w_f + 1)
        p_arr = t + offs_arr
        ctx_valid = jnp.logical_and(p_arr >= 0, p_arr < length)
        ctx_valid = jnp.logical_and(
            ctx_valid,
            jax.lax.iota(jnp.int32, k_pad) < k)
        out_valid = jax.lax.iota(jnp.int32, m_pad) < m
        g = jnp.where(ctx_valid[:, None], g, 0.0)
        g = jnp.where(out_valid[None, :], g, 0.0)

        d_ctx = jax.lax.dot_general(
            g, out_rows, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)    # (k_pad, d)
        d_out = jax.lax.dot_general(
            g, ctx, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)    # (m_pad, d)

        # ---- apply: context deltas accumulate in the ring buffer ----
        for j, off in enumerate(offs):
            p = t + off
            slot = jnp.clip(p, 0, L - 1) % r
            ring[pl.ds(slot, 1), :] = (ring[pl.ds(slot, 1), :]
                                       + d_ctx[j:j + 1, :])

        # ---- output rows: update in VMEM, write back once per window ----
        out_blk[...] = out_rows + d_out
        copy(out_blk.at[pl.ds(0, 1)], w_out_out.at[pl.ds(tgt, 1)])
        for j in range(n_neg):
            neg = negs_ref[0, t, j]
            copy(out_blk.at[pl.ds(1 + j, 1)], w_out_out.at[pl.ds(neg, 1)])
        return 0

    def guarded_step(t, c):
        @pl.when(t < length)
        def _():
            step(t, c)
        return 0

    jax.lax.fori_loop(0, L, guarded_step, 0)

    # --- flush surviving ring entries (increasing position order) ---
    def flush(kk, _):
        p = length - r + kk

        @pl.when(jnp.logical_and(p >= 0, p < length))
        def _():
            store_ring(p)
        return 0

    jax.lax.fori_loop(0, r, flush, 0, unroll=True)


def _kernel_pipelined(
    tokens_ref, negs_ref, length_ref, lr_ref,
    w_in_hbm, w_out_hbm, w_in_out, w_out_out,
    ring, ctx_blk, out_dbl, sem_ring, sem_out,
    *, w_f: int, n_neg: int,
):
    """FULL-W2V kernel with §3.1-style prefetch: window t+1's target +
    negative rows are DMA'd into the other half of a double buffer WHILE
    window t computes — the TPU realization of the paper's "interleaving
    memory demand and computation".

    Correctness: a prefetched row whose index collides with one of window
    t's output rows would read a stale value (window t writes it back after
    compute). Collisions are detected at trace-recomputable scalar cost
    (m×m index compares); colliding rows are NOT prefetched and are loaded
    synchronously after window t's write-back instead — bit-identical
    semantics to the sequential kernel, overlap in the common
    (collision-free) case.
    """
    L = tokens_ref.shape[1]
    d = w_in_hbm.shape[1]
    r = 2 * w_f + 1
    k = 2 * w_f
    m = n_neg + 1
    k_pad = ctx_blk.shape[0]
    m_pad = out_dbl.shape[1]
    length = length_ref[0]
    lr = lr_ref[0]

    def copy(src, dst, sem):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def row_idx(t, j):
        return jnp.where(j == 0, tokens_ref[0, t],
                         negs_ref[0, t, jnp.maximum(j - 1, 0)])

    def conflicts_prev(t, j):
        """Does row j of window t collide with any output row of window
        t-1? (t >= 1)"""
        idx = row_idx(t, j)
        hit = jnp.bool_(False)
        for i in range(m):
            hit = jnp.logical_or(hit, idx == row_idx(t - 1, i))
        return hit

    def start_prefetch(t, buf):
        """Begin async loads of window t's non-colliding rows into half
        `buf`."""
        for j in range(m):
            idx = row_idx(t, j)

            @pl.when(jnp.logical_or(t == 0, ~conflicts_prev(t, j)))
            def _():
                pltpu.make_async_copy(
                    w_out_out.at[pl.ds(idx, 1)],
                    out_dbl.at[buf, pl.ds(j, 1)],
                    sem_out.at[buf]).start()

    def load_ring(q):
        copy(w_in_out.at[pl.ds(tokens_ref[0, q], 1)],
             ring.at[pl.ds(q % r, 1)], sem_ring)

    def store_ring(p):
        copy(ring.at[pl.ds(p % r, 1)],
             w_in_out.at[pl.ds(tokens_ref[0, p], 1)], sem_ring)

    # --- preload ring positions 0..w_f-1 and prefetch window 0 rows ---
    def preload(q, _):
        @pl.when(q < length)
        def _():
            load_ring(q)
        return 0

    jax.lax.fori_loop(0, min(w_f, L), preload, 0, unroll=True)

    @pl.when(length > 0)
    def _():
        start_prefetch(0, 0)

    def step(t, _):
        buf = jax.lax.rem(t, 2)
        q = t + w_f

        @pl.when(q < length)
        def _():
            @pl.when(q - r >= 0)
            def _():
                store_ring(q - r)
            load_ring(q)

        # ---- wait for this window's prefetched rows / sync-load the
        # colliding ones (window t-1's write-back already happened) ----
        for j in range(m):
            idx = row_idx(t, j)
            prefetched = jnp.logical_or(t == 0, ~conflicts_prev(t, j))

            @pl.when(prefetched)
            def _():
                pltpu.make_async_copy(
                    w_out_out.at[pl.ds(idx, 1)],
                    out_dbl.at[buf, pl.ds(j, 1)],
                    sem_out.at[buf]).wait()

            @pl.when(~prefetched)
            def _():
                copy(w_out_out.at[pl.ds(idx, 1)],
                     out_dbl.at[buf, pl.ds(j, 1)], sem_ring)

        if m_pad > m:
            out_dbl[buf, pl.ds(m, m_pad - m), :] = jnp.zeros(
                (m_pad - m, d), out_dbl.dtype)

        # ---- overlap: begin prefetch of window t+1 into the other half ----
        @pl.when(t + 1 < length)
        def _():
            start_prefetch(t + 1, 1 - buf)

        # ---- gather context rows ----
        offs = [o for o in range(-w_f, w_f + 1) if o != 0]
        for j, off in enumerate(offs):
            p = t + off
            valid = jnp.logical_and(p >= 0, p < length)
            slot = jnp.clip(p, 0, L - 1) % r
            row = ring[pl.ds(slot, 1), :]
            ctx_blk[pl.ds(j, 1), :] = jnp.where(valid, row, 0.0)
        if k_pad > k:
            ctx_blk[pl.ds(k, k_pad - k), :] = jnp.zeros((k_pad - k, d),
                                                        ctx_blk.dtype)

        # ---- window GEMMs (same math as the sequential kernel) ----
        ctx = ctx_blk[...]
        out_rows = out_dbl[buf]
        corr = jax.lax.dot_general(
            ctx, out_rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        f = jnp.where(corr >= 0, 1.0 / (1.0 + jnp.exp(-corr)),
                      jnp.exp(corr) / (1.0 + jnp.exp(corr)))
        label = (jax.lax.broadcasted_iota(jnp.int32, (k_pad, m_pad), 1)
                 == 0).astype(jnp.float32)
        g = lr * (label - f)
        ji = jax.lax.iota(jnp.int32, k_pad)
        offs_arr = jnp.where(ji < w_f, ji - w_f, ji - w_f + 1)
        p_arr = t + offs_arr
        ctx_valid = jnp.logical_and(p_arr >= 0, p_arr < length)
        ctx_valid = jnp.logical_and(ctx_valid,
                                    jax.lax.iota(jnp.int32, k_pad) < k)
        out_valid = jax.lax.iota(jnp.int32, m_pad) < m
        g = jnp.where(ctx_valid[:, None], g, 0.0)
        g = jnp.where(out_valid[None, :], g, 0.0)
        d_ctx = jax.lax.dot_general(
            g, out_rows, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_out = jax.lax.dot_general(
            g, ctx, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        for j, off in enumerate(offs):
            p = t + off
            slot = jnp.clip(p, 0, L - 1) % r
            ring[pl.ds(slot, 1), :] = (ring[pl.ds(slot, 1), :]
                                       + d_ctx[j:j + 1, :])

        out_dbl[buf] = out_rows + d_out
        for j in range(m):
            idx = row_idx(t, j)
            copy(out_dbl.at[buf, pl.ds(j, 1)],
                 w_out_out.at[pl.ds(idx, 1)], sem_ring)
        return 0

    def guarded_step(t, c):
        @pl.when(t < length)
        def _():
            step(t, c)
        return 0

    jax.lax.fori_loop(0, L, guarded_step, 0)

    def flush(kk, _):
        p = length - r + kk

        @pl.when(jnp.logical_and(p >= 0, p < length))
        def _():
            store_ring(p)
        return 0

    jax.lax.fori_loop(0, r, flush, 0, unroll=True)


def fullw2v_pallas(
    w_in: jax.Array,     # (V, d) f32
    w_out: jax.Array,    # (V, d) f32
    tokens: jax.Array,   # (S, L) int32
    negs: jax.Array,     # (S, L, N) int32
    lengths: jax.Array,  # (S,) int32
    lr: jax.Array,       # scalar f32
    w_f: int,
    interpret: bool = False,
    pipeline: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One FULL-W2V training pass over a batch of sentences."""
    S, L = tokens.shape
    n_neg = negs.shape[-1]
    V, d = w_in.shape
    assert d % LANE == 0, f"embedding dim {d} must be a multiple of {LANE}"
    r = 2 * w_f + 1
    r_pad = _round_up(r, SUBLANE)
    k_pad = _round_up(2 * w_f, SUBLANE)
    m_pad = _round_up(n_neg + 1, SUBLANE)

    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))

    grid = (S,)
    if pipeline:
        kernel = functools.partial(_kernel_pipelined, w_f=w_f, n_neg=n_neg)
        scratch = [
            pltpu.VMEM((r_pad, d), jnp.float32),
            pltpu.VMEM((k_pad, d), jnp.float32),
            pltpu.VMEM((2, m_pad, d), jnp.float32),   # double buffer
            pltpu.SemaphoreType.DMA,                   # ring/stores
            pltpu.SemaphoreType.DMA((2,)),             # per-half prefetch
        ]
    else:
        kernel = functools.partial(_kernel, w_f=w_f, n_neg=n_neg)
        scratch = [
            pltpu.VMEM((r_pad, d), jnp.float32),
            pltpu.VMEM((k_pad, d), jnp.float32),
            pltpu.VMEM((m_pad, d), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, L, n_neg), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda s: (s,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, d), w_in.dtype),
            jax.ShapeDtypeStruct((V, d), w_out.dtype),
        ],
        scratch_shapes=scratch,
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(tokens, negs, lengths, lr_arr, w_in, w_out)
    return out[0], out[1]
