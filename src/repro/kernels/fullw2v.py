"""FULL-W2V Pallas TPU kernel.

The paper's two mechanisms, mapped to TPU (DESIGN.md §2):

* *Lifetime reuse of context words* (§3.2): a VMEM scratch ring buffer of
  ``R = 2*W_f + 1`` embedding rows mirrors the sliding window. Each context
  row is DMA'd HBM→VMEM once when it enters the window, accumulates all its
  updates in VMEM, and is DMA'd back exactly once when it leaves — removing
  2W_f/(2W_f+1) of context-row HBM traffic.

* *Independence of negative samples* (§3.1): the N+1 output rows of a window
  (target + shared negatives) are DMA'd into a VMEM block, used for every
  pairing of the window from that block (the GPU's register caching), and
  written back once per window. Because all pairings commute, the window
  update is expressed as two tiny GEMMs over data already resident in VMEM —
  the MXU-native analogue of the paper's per-negative register loop.

Three kernel variants share the window math (``_window_update``):

* ``_kernel``            — one window per inner step, strict ordering.
* ``_kernel_pipelined``  — same semantics; window t+1's output rows prefetch
  while window t computes (§3.1 "interleaving memory and computation").
* ``_kernel_tiled``      — T consecutive windows fused per inner step
  (DESIGN.md §4): the ring grows to ``T + 2*W_f`` positions, the tile's
  context rows are gathered into one ``(T*K, d)`` block, its output rows are
  deduplicated host-side (`repro.data.batching.plan_tiles`) and fetched as
  one batched multi-row DMA, and the update becomes two large MXU-shaped
  GEMMs — amortizing MXU and DMA-setup latency over T windows. Tiles whose
  output rows collide across windows run the exact sequential path
  (``strict`` bit); collision-free tiles trade strict intra-tile ordering
  for throughput (all T windows read pre-tile values — the HogBatch
  relaxation of Ji et al. 1604.04661; quality impact measured by
  ``benchmarks/bench_tile_sweep.py``). At T=1 the tiled kernel is
  bit-identical to ``_kernel``.

Grid = one step per sentence; the TPU grid is sequential per core, so strict
context-window ordering (required for convergence, paper §3.1) holds by
construction, and batch-level parallelism comes from data parallelism across
cores/chips (Hogwild, as in the paper). The host entry points below are
registered with the engine API (``kernels.registry``) as the ``pallas``,
``pallas_pipelined``, ``pallas_tiled``, and ``*_interpret`` backends;
training code reaches them through ``kernels.ops.step``.

Embedding tables stay in HBM (``memory_space=ANY``); rows move via explicit
``make_async_copy`` — the TPU spelling of the paper's explicit caching.

PRECONDITION (enforced by the host batching pipeline, `repro.data.negatives`,
exactly as the paper performs negative selection on the CPU): within one
window the N negatives are distinct from each other and from the target.
Under this invariant the kernel is bit-identical to `kernels.ref`; with
duplicates the kernel's per-row write-back is last-write-wins while the
oracle scatter-adds (the GPU original has the same benign race).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.w2v import resolve_gemm_windows

LANE = 128     # TPU lane width; embedding dim must be a multiple
SUBLANE = 8    # f32 sublane tile


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def tiled_scratch_rows(tile: int, w_f: int, n_neg: int,
                       gemm_windows: int = 0) -> dict:
    """Padded scratch-row counts of `_kernel_tiled`, keyed like its scratch
    operands (ring/ctx_tile/out_uniq/out_exp/ctx_win/out_win). Single source
    of truth shared with `benchmarks/bench_tile_sweep` so VMEM reporting
    stays in lockstep with the kernel."""
    g = resolve_gemm_windows(tile, gemm_windows)
    m = n_neg + 1
    return {
        "ring": _round_up(tile + 2 * w_f, SUBLANE),
        "ctx_tile": _round_up(g * 2 * w_f, SUBLANE),
        "out_uniq": _round_up(tile * m, SUBLANE),
        "out_exp": _round_up(g * m, SUBLANE),
        "ctx_win": _round_up(2 * w_f, SUBLANE),
        "out_win": _round_up(m, SUBLANE),
    }


def _ctx_offsets(w_f: int) -> list:
    """Window-relative context offsets [-w_f..w_f] \\ {0}."""
    return [o for o in range(-w_f, w_f + 1) if o != 0]


# ---------------------------------------------------------------------------
# Shared building blocks (used by all three kernel variants)
# ---------------------------------------------------------------------------

class _Table:
    """A row-addressed HBM embedding table, optionally *split* (DESIGN.md
    §8 fused gather): rows ``[0, hot)`` live in ``main`` (the replicated
    hot head), rows ``>= hot`` in ``got`` at ``row - hot`` (the gathered
    cold block delivered by the request-exact exchange). The kernel streams
    rows from whichever buffer owns them — both directions of every DMA
    branch on the row index at trace-recomputable scalar cost — so a
    vocab-sharded step never materializes ``concat(hot, gathered)``. With
    ``got=None`` the helpers are exactly the single-table DMA calls."""

    def __init__(self, main, got=None, hot: int = 0):
        self.main, self.got, self.hot = main, got, hot

    def _each(self, row):
        """(predicate, hbm_slice) per buffer; predicate None = always."""
        if self.got is None:
            yield None, self.main.at[pl.ds(row, 1)]
        else:
            lo = jnp.minimum(row, self.hot - 1)
            hi = jnp.maximum(row - self.hot, 0)
            yield row < self.hot, self.main.at[pl.ds(lo, 1)]
            yield row >= self.hot, self.got.at[pl.ds(hi, 1)]

    def _move(self, row, vmem, sem, to_hbm: bool, op: str):
        for pred, hbm in self._each(row):
            src, dst = (vmem, hbm) if to_hbm else (hbm, vmem)
            if pred is None:
                getattr(pltpu.make_async_copy(src, dst, sem), op)()
            else:
                @pl.when(pred)
                def _(src=src, dst=dst):
                    getattr(pltpu.make_async_copy(src, dst, sem), op)()

    # start/wait split so callers can batch DMAs (start all, wait all);
    # the wait call rebuilds the same descriptor under the same predicate
    def start_load(self, row, vmem, sem):
        self._move(row, vmem, sem, to_hbm=False, op="start")

    def wait_load(self, row, vmem, sem):
        self._move(row, vmem, sem, to_hbm=False, op="wait")

    def start_store(self, vmem, row, sem):
        self._move(row, vmem, sem, to_hbm=True, op="start")

    def wait_store(self, vmem, row, sem):
        self._move(row, vmem, sem, to_hbm=True, op="wait")

    def load(self, row, vmem, sem):
        self.start_load(row, vmem, sem)
        self.wait_load(row, vmem, sem)

    def store(self, vmem, row, sem):
        self.start_store(vmem, row, sem)
        self.wait_store(vmem, row, sem)


def _window_update(ctx, out_rows, label, mask, lr):
    """The SGNS window update (DESIGN.md §2) on VMEM-resident blocks.

    ctx      : (K, d) f32 — gathered context rows (zeros where invalid)
    out_rows : (M, d) f32 — target + negative rows
    label    : (K, M) f32 — 1 where the pairing is (context, its target)
    mask     : (K, M) bool — which pairings are real (window membership,
               sentence edges, padding)
    Returns (d_ctx (K, d), d_out (M, d)) gradient blocks.
    """
    # function-level import: repro.core.__init__ pulls in the trainer →
    # ops → this module, so a top-level import would be circular
    from repro.core.sgns import stable_sigmoid

    corr = jax.lax.dot_general(
        ctx, out_rows, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (K, M)
    g = lr * (label - stable_sigmoid(corr))
    g = jnp.where(mask, g, 0.0)
    d_ctx = jax.lax.dot_general(
        g, out_rows, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (K, d)
    d_out = jax.lax.dot_general(
        g, ctx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (M, d)
    return d_ctx, d_out


def _zero_rows(blk, start: int, stop: int):
    """Zero scratch rows [start, stop) (uninitialized VMEM may hold NaNs)."""
    if stop > start:
        blk[pl.ds(start, stop - start), :] = jnp.zeros(
            (stop - start, blk.shape[-1]), blk.dtype)


def _gather_window_ctx(ring, ctx_blk, t, dst0: int, *, w_f: int, r: int,
                       length, L: int):
    """Copy window t's context rows from the VMEM ring (no HBM traffic) into
    ctx_blk rows [dst0, dst0 + 2*w_f); out-of-sentence positions read 0."""
    for j, off in enumerate(_ctx_offsets(w_f)):
        p = t + off
        valid = jnp.logical_and(p >= 0, p < length)
        slot = jnp.clip(p, 0, L - 1) % r
        row = ring[pl.ds(slot, 1), :]
        ctx_blk[pl.ds(dst0 + j, 1), :] = jnp.where(valid, row, 0.0)


def _scatter_window_ctx(ring, d_ctx, t, src0: int, *, w_f: int, r: int,
                        L: int):
    """Accumulate window t's context deltas back into the ring. Invalid
    positions carry zero gradient (masked in `_window_update`), so the
    clipped-slot add is a no-op for them."""
    for j, off in enumerate(_ctx_offsets(w_f)):
        p = t + off
        slot = jnp.clip(p, 0, L - 1) % r
        ring[pl.ds(slot, 1), :] = (ring[pl.ds(slot, 1), :]
                                   + d_ctx[src0 + j:src0 + j + 1, :])


def _ctx_valid(t, k_pad: int, *, w_f: int, length):
    """(k_pad,) bool — which context slots of window t are real words.
    Rebuilds the static offset list with iota (no captured constants):
    j < w_f -> j - w_f;  j >= w_f -> j - w_f + 1 (skipping offset 0)."""
    ji = jax.lax.iota(jnp.int32, k_pad)
    offs_arr = jnp.where(ji < w_f, ji - w_f, ji - w_f + 1)
    p_arr = t + offs_arr
    valid = jnp.logical_and(p_arr >= 0, p_arr < length)
    return jnp.logical_and(valid, ji < 2 * w_f)


def _window_label_mask(t, k_pad: int, m_pad: int, *, w_f: int, n_neg: int,
                       length):
    """Label + validity mask for a single window's (k_pad, m_pad) update."""
    label = (jax.lax.broadcasted_iota(jnp.int32, (k_pad, m_pad), 1)
             == 0).astype(jnp.float32)
    out_valid = jax.lax.iota(jnp.int32, m_pad) < n_neg + 1
    mask = jnp.logical_and(
        _ctx_valid(t, k_pad, w_f=w_f, length=length)[:, None],
        out_valid[None, :])
    return label, mask


def _seq_window(t, tokens_ref, negs_ref, w_out_tab, ring, ctx_blk, out_blk,
                sem, *, w_f: int, n_neg: int, r: int, length, L: int, lr):
    """One strictly-ordered window update (fetch → GEMMs → apply → write
    back). Shared by `_kernel` and `_kernel_tiled`'s strict fallback; `r` is
    the caller's ring size (2*w_f+1 sequential, T+2*w_f tiled);
    ``w_out_tab`` is a :class:`_Table` (split under fused gather)."""
    k = 2 * w_f
    m = n_neg + 1
    k_pad = ctx_blk.shape[0]
    m_pad = out_blk.shape[0]

    # ---- gather context rows (from VMEM ring — no HBM traffic) ----
    _gather_window_ctx(ring, ctx_blk, t, 0, w_f=w_f, r=r, length=length, L=L)
    _zero_rows(ctx_blk, k, k_pad)

    # ---- fetch output rows: target + shared negatives (paper §3.1) ----
    tgt = tokens_ref[0, t]
    w_out_tab.load(tgt, out_blk.at[pl.ds(0, 1)], sem)
    for j in range(n_neg):
        neg = negs_ref[0, t, j]
        w_out_tab.load(neg, out_blk.at[pl.ds(1 + j, 1)], sem)
    _zero_rows(out_blk, m, m_pad)

    # ---- the window update: two tiny GEMMs on VMEM-resident data ----
    ctx = ctx_blk[...]
    out_rows = out_blk[...]
    label, mask = _window_label_mask(t, k_pad, m_pad, w_f=w_f, n_neg=n_neg,
                                     length=length)
    d_ctx, d_out = _window_update(ctx, out_rows, label, mask, lr)

    # ---- apply: context deltas accumulate in the ring buffer ----
    _scatter_window_ctx(ring, d_ctx, t, 0, w_f=w_f, r=r, L=L)

    # ---- output rows: update in VMEM, write back once per window ----
    out_blk[...] = out_rows + d_out
    w_out_tab.store(out_blk.at[pl.ds(0, 1)], tgt, sem)
    for j in range(n_neg):
        neg = negs_ref[0, t, j]
        w_out_tab.store(out_blk.at[pl.ds(1 + j, 1)], neg, sem)


# ---------------------------------------------------------------------------
# Variant 1: sequential (one window per step)
# ---------------------------------------------------------------------------

def _kernel(
    # --- scalar/SMEM inputs (per sentence block) ---
    tokens_ref,    # (1, L) int32  SMEM
    negs_ref,      # (1, L, N) int32 SMEM
    length_ref,    # (1,) int32 SMEM
    lr_ref,        # (1,) f32 SMEM
    # --- HBM (ANY) inputs, aliased to outputs ---
    w_in_hbm,      # (V, d)
    w_out_hbm,     # (V, d)
    # --- outputs (aliased) ---
    w_in_out,      # (V, d)
    w_out_out,     # (V, d)
    # --- scratch ---
    ring,          # (R_pad, d) f32 VMEM — context-row ring buffer
    ctx_blk,       # (K_pad, d) f32 VMEM — gathered window context rows
    out_blk,       # (M_pad, d) f32 VMEM — target + negative output rows
    sem,           # DMA semaphore
    *,
    w_f: int,
    n_neg: int,
):
    """See module docstring; `_kernel_pipelined` adds §3.1-style prefetch."""
    L = tokens_ref.shape[1]
    r = 2 * w_f + 1
    length = length_ref[0]
    lr = lr_ref[0]

    def copy(src, dst):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def load_ring(q):
        """HBM w_in row tokens[q] -> ring slot q % r."""
        tok = tokens_ref[0, q]
        copy(w_in_out.at[pl.ds(tok, 1)], ring.at[pl.ds(q % r, 1)])

    def store_ring(p):
        """ring slot p % r -> HBM w_in row tokens[p] (write-through output)."""
        tok = tokens_ref[0, p]
        copy(ring.at[pl.ds(p % r, 1)], w_in_out.at[pl.ds(tok, 1)])

    # --- preload positions 0..w_f-1 ---
    def preload(q, _):
        @pl.when(q < length)
        def _():
            load_ring(q)
        return 0

    jax.lax.fori_loop(0, min(w_f, L), preload, 0, unroll=True)

    # --- main sliding-window loop ---
    def step(t, _):
        # evict + load leading edge
        q = t + w_f

        @pl.when(q < length)
        def _():
            @pl.when(q - r >= 0)
            def _():
                store_ring(q - r)
            load_ring(q)

        _seq_window(t, tokens_ref, negs_ref, _Table(w_out_out), ring,
                    ctx_blk, out_blk, sem, w_f=w_f, n_neg=n_neg, r=r,
                    length=length, L=L, lr=lr)
        return 0

    def guarded_step(t, c):
        @pl.when(t < length)
        def _():
            step(t, c)
        return 0

    jax.lax.fori_loop(0, L, guarded_step, 0)

    # --- flush surviving ring entries (increasing position order) ---
    def flush(kk, _):
        p = length - r + kk

        @pl.when(jnp.logical_and(p >= 0, p < length))
        def _():
            store_ring(p)
        return 0

    jax.lax.fori_loop(0, r, flush, 0, unroll=True)


# ---------------------------------------------------------------------------
# Variant 2: pipelined (prefetch window t+1's rows while t computes)
# ---------------------------------------------------------------------------

def _kernel_pipelined(
    tokens_ref, negs_ref, length_ref, lr_ref,
    w_in_hbm, w_out_hbm, w_in_out, w_out_out,
    ring, ctx_blk, out_dbl, sem_ring, sem_out,
    *, w_f: int, n_neg: int,
):
    """FULL-W2V kernel with §3.1-style prefetch: window t+1's target +
    negative rows are DMA'd into the other half of a double buffer WHILE
    window t computes — the TPU realization of the paper's "interleaving
    memory demand and computation".

    Correctness: a prefetched row whose index collides with one of window
    t's output rows would read a stale value (window t writes it back after
    compute). Collisions are detected at trace-recomputable scalar cost
    (m×m index compares); colliding rows are NOT prefetched and are loaded
    synchronously after window t's write-back instead — bit-identical
    semantics to the sequential kernel, overlap in the common
    (collision-free) case.
    """
    L = tokens_ref.shape[1]
    d = w_in_hbm.shape[1]
    r = 2 * w_f + 1
    k = 2 * w_f
    m = n_neg + 1
    k_pad = ctx_blk.shape[0]
    m_pad = out_dbl.shape[1]
    length = length_ref[0]
    lr = lr_ref[0]

    def copy(src, dst, sem):
        cp = pltpu.make_async_copy(src, dst, sem)
        cp.start()
        cp.wait()

    def row_idx(t, j):
        return jnp.where(j == 0, tokens_ref[0, t],
                         negs_ref[0, t, jnp.maximum(j - 1, 0)])

    def conflicts_prev(t, j):
        """Does row j of window t collide with any output row of window
        t-1? (t >= 1)"""
        idx = row_idx(t, j)
        hit = jnp.bool_(False)
        for i in range(m):
            hit = jnp.logical_or(hit, idx == row_idx(t - 1, i))
        return hit

    def start_prefetch(t, buf):
        """Begin async loads of window t's non-colliding rows into half
        `buf`."""
        for j in range(m):
            idx = row_idx(t, j)

            @pl.when(jnp.logical_or(t == 0, ~conflicts_prev(t, j)))
            def _():
                pltpu.make_async_copy(
                    w_out_out.at[pl.ds(idx, 1)],
                    out_dbl.at[buf, pl.ds(j, 1)],
                    sem_out.at[buf]).start()

    def load_ring(q):
        copy(w_in_out.at[pl.ds(tokens_ref[0, q], 1)],
             ring.at[pl.ds(q % r, 1)], sem_ring)

    def store_ring(p):
        copy(ring.at[pl.ds(p % r, 1)],
             w_in_out.at[pl.ds(tokens_ref[0, p], 1)], sem_ring)

    # --- preload ring positions 0..w_f-1 and prefetch window 0 rows ---
    def preload(q, _):
        @pl.when(q < length)
        def _():
            load_ring(q)
        return 0

    jax.lax.fori_loop(0, min(w_f, L), preload, 0, unroll=True)

    @pl.when(length > 0)
    def _():
        start_prefetch(0, 0)

    def step(t, _):
        buf = jax.lax.rem(t, 2)
        q = t + w_f

        @pl.when(q < length)
        def _():
            @pl.when(q - r >= 0)
            def _():
                store_ring(q - r)
            load_ring(q)

        # ---- wait for this window's prefetched rows / sync-load the
        # colliding ones (window t-1's write-back already happened) ----
        for j in range(m):
            idx = row_idx(t, j)
            prefetched = jnp.logical_or(t == 0, ~conflicts_prev(t, j))

            @pl.when(prefetched)
            def _():
                pltpu.make_async_copy(
                    w_out_out.at[pl.ds(idx, 1)],
                    out_dbl.at[buf, pl.ds(j, 1)],
                    sem_out.at[buf]).wait()

            @pl.when(~prefetched)
            def _():
                copy(w_out_out.at[pl.ds(idx, 1)],
                     out_dbl.at[buf, pl.ds(j, 1)], sem_ring)

        if m_pad > m:
            out_dbl[buf, pl.ds(m, m_pad - m), :] = jnp.zeros(
                (m_pad - m, d), out_dbl.dtype)

        # ---- overlap: begin prefetch of window t+1 into the other half ----
        @pl.when(t + 1 < length)
        def _():
            start_prefetch(t + 1, 1 - buf)

        # ---- gather context + window GEMMs (shared helpers) ----
        _gather_window_ctx(ring, ctx_blk, t, 0, w_f=w_f, r=r, length=length,
                           L=L)
        _zero_rows(ctx_blk, k, k_pad)
        ctx = ctx_blk[...]
        out_rows = out_dbl[buf]
        label, mask = _window_label_mask(t, k_pad, m_pad, w_f=w_f,
                                         n_neg=n_neg, length=length)
        d_ctx, d_out = _window_update(ctx, out_rows, label, mask, lr)

        _scatter_window_ctx(ring, d_ctx, t, 0, w_f=w_f, r=r, L=L)

        out_dbl[buf] = out_rows + d_out
        for j in range(m):
            idx = row_idx(t, j)
            copy(out_dbl.at[buf, pl.ds(j, 1)],
                 w_out_out.at[pl.ds(idx, 1)], sem_ring)
        return 0

    def guarded_step(t, c):
        @pl.when(t < length)
        def _():
            step(t, c)
        return 0

    jax.lax.fori_loop(0, L, guarded_step, 0)

    def flush(kk, _):
        p = length - r + kk

        @pl.when(jnp.logical_and(p >= 0, p < length))
        def _():
            store_ring(p)
        return 0

    jax.lax.fori_loop(0, r, flush, 0, unroll=True)


# ---------------------------------------------------------------------------
# Variant 3: tiled (T windows fused per step, DESIGN.md §4)
# ---------------------------------------------------------------------------

def _kernel_tiled(
    # --- scalar/SMEM inputs (per sentence block) ---
    tokens_ref,    # (1, L) int32 SMEM
    negs_ref,      # (1, L, N) int32 SMEM
    length_ref,    # (1,) int32 SMEM
    lr_ref,        # (1,) f32 SMEM
    uniq_ref,      # (1, nt, T*m) int32 SMEM — compacted unique output rows
    scat_ref,      # (1, nt, T*m) int32 SMEM — slot -> uniq column
    ucount_ref,    # (1, nt) int32 SMEM — valid uniq columns per tile
    strict_ref,    # (1, nt) int32 SMEM — 1: sequential fallback tile
    # --- HBM (ANY) inputs + aliased outputs + scratch, layout depends on
    # hot_rows/prefetch (see the unpacking right below):
    #   hot_rows == 0: w_in_hbm, w_out_hbm, w_in_out, w_out_out
    #   hot_rows > 0 : hot/got in/out pairs (8 refs, fused-gather split)
    # then: ring (Rt_pad, d), ctx_tile (GK_pad, d), out_uniq
    # (n_buf, U_pad, d), out_exp (GM_pad, d), ctx_win (k_pad, d), out_win
    # (m_pad, d), sem [, sem_pf (2,) when prefetch]
    *refs,
    w_f: int,
    n_neg: int,
    tile: int,
    gemm_windows: int,
    hot_rows: int = 0,
    prefetch: bool = False,
):
    """T consecutive windows per step. Collision-free tiles (host `strict`
    bit clear) fetch the tile's deduplicated output rows as one batched DMA,
    then update in GEMM groups of ``G = gemm_windows`` windows: each group
    runs two (G*K, G*m, d) MXU-shaped GEMMs and applies its deltas to the
    VMEM ring and out_uniq block before the next group reads them — so DMA
    amortizes over the whole tile while value staleness is bounded by G
    (DESIGN.md §4). Strict tiles replay the exact sequential path.

    With ``hot_rows > 0`` (fused gather, DESIGN.md §8) the working table
    arrives *split* — hot replica + gathered cold block — and every row DMA
    routes through :class:`_Table`. With ``prefetch`` (the fused-gather
    entry point) ``out_uniq`` is double-buffered across tiles: while tile i
    runs its GEMM groups, tile i+1's unique output rows stream HBM→VMEM
    into the other half, and only rows colliding with tile i's write-back
    set (detected by trace-recomputable SMEM compares, like
    ``_kernel_pipelined``) are re-fetched synchronously — so cold-row fetch
    overlaps window compute instead of serializing ahead of it."""
    n_tab = 4 if hot_rows else 2
    outs = refs[n_tab:2 * n_tab]
    scratch = refs[2 * n_tab:]
    if hot_rows:
        w_in_tab = _Table(outs[0], outs[2], hot_rows)
        w_out_tab = _Table(outs[1], outs[3], hot_rows)
    else:
        w_in_tab = _Table(outs[0])
        w_out_tab = _Table(outs[1])
    if prefetch:
        (ring, ctx_tile, out_uniq, out_exp, ctx_win, out_win, sem,
         sem_pf) = scratch
    else:
        ring, ctx_tile, out_uniq, out_exp, ctx_win, out_win, sem = scratch
        sem_pf = None

    L = tokens_ref.shape[1]
    nt = uniq_ref.shape[1]
    rt = tile + 2 * w_f            # ring positions covering the whole tile
    k = 2 * w_f
    m = n_neg + 1
    M = tile * m                   # output slots per tile
    G = gemm_windows
    gk_pad = ctx_tile.shape[0]
    gm_pad = out_exp.shape[0]
    u_pad = out_uniq.shape[1]
    k_pad = ctx_win.shape[0]
    m_pad = out_win.shape[0]
    d = ring.shape[-1]
    length = length_ref[0]
    lr = lr_ref[0]

    def load_ring(q):
        w_in_tab.load(tokens_ref[0, q], ring.at[pl.ds(q % rt, 1)], sem)

    def store_ring(p):
        w_in_tab.store(ring.at[pl.ds(p % rt, 1)], tokens_ref[0, p], sem)

    def was_prefetched(ti, c):
        """Was uniq column c of tile ti prefetched during tile ti-1? A pure
        function of SMEM state, evaluated identically at the start site
        (tile ti-1) and the wait site (tile ti): both tiles must run the
        fused path, c must be a real column, and the row must not collide
        with tile ti-1's write-back set (a stale prefetch otherwise)."""
        tc = jnp.clip(ti, 0, nt - 1)
        pv = jnp.maximum(tc - 1, 0)
        ok = ((ti > 0) & (ti < nt) & (ti * tile < length)
              & (strict_ref[0, tc] == 0) & (strict_ref[0, pv] == 0)
              & (c < ucount_ref[0, tc]))
        idx = uniq_ref[0, tc, c]
        hit = jnp.bool_(False)
        for cc in range(M):
            hit = jnp.logical_or(
                hit, jnp.logical_and(cc < ucount_ref[0, pv],
                                     idx == uniq_ref[0, pv, cc]))
        return jnp.logical_and(ok, ~hit)

    # --- preload positions 0..w_f-1 ---
    def preload(q, _):
        @pl.when(q < length)
        def _():
            load_ring(q)
        return 0

    jax.lax.fori_loop(0, min(w_f, L), preload, 0, unroll=True)

    def advance_window(t):
        """Seed-kernel ring advance for window t: store the r-distance
        evictee (its updates are complete), then load the leading edge.
        The *slot* modulus is rt (big ring: rows stay resident for context
        reads across the tile) but the *store schedule* is the sequential
        kernel's r-distance one. Strict tiles call this per window, so
        their loads see HBM exactly as fresh as under `_kernel`; in fused
        tiles only group window 0 goes through here — the remaining G-1
        loads run ahead of their evictees' stores, which widens the seed
        kernel's benign duplicate-token race from distance < r to
        < r + G - 1 (DESIGN.md §4)."""
        q = t + w_f

        @pl.when(q < length)
        def _():
            @pl.when(q - r_seq >= 0)
            def _():
                store_ring(q - r_seq)
            load_ring(q)

    r_seq = 2 * w_f + 1            # sequential store distance

    def tile_step(i, _):
        t0 = i * tile
        strict = strict_ref[0, i] != 0
        # double-buffer parity: tile i's rows live in half i % 2 (half 0
        # always when the prefetch stage is off)
        buf = jax.lax.rem(i, 2) if prefetch else 0

        # ---- strict fallback: bit-identical sequential replay (the ring
        # advance interleaves per window exactly as `_kernel`) ----
        @pl.when(strict)
        def _():
            for w in range(tile):
                t = t0 + w

                @pl.when(t < length)
                def _():
                    advance_window(t)
                    _seq_window(t, tokens_ref, negs_ref, w_out_tab, ring,
                                ctx_win, out_win, sem, w_f=w_f, n_neg=n_neg,
                                r=rt, length=length, L=L, lr=lr)

        # ---- fused path: one batched fetch per tile + per-group GEMMs ----
        @pl.when(~strict)
        def _():
            # batched multi-row fetch of the deduplicated output rows:
            # issue every start, then wait — one DMA-latency exposure per
            # tile instead of one per row (paper §3.1 amortization). Rows
            # already in flight from the previous tile's prefetch stage
            # only need their wait.
            u = ucount_ref[0, i]
            for c in range(M):
                fetch = c < u
                if prefetch:
                    fetch = jnp.logical_and(fetch, ~was_prefetched(i, c))

                @pl.when(fetch)
                def _(c=c):
                    w_out_tab.start_load(uniq_ref[0, i, c],
                                         out_uniq.at[buf, pl.ds(c, 1)], sem)

                @pl.when(~(c < u))
                def _(c=c):
                    out_uniq[buf, pl.ds(c, 1), :] = jnp.zeros(
                        (1, d), out_uniq.dtype)
            for c in range(M):
                fetch = c < u
                if prefetch:
                    pf = was_prefetched(i, c)
                    fetch = jnp.logical_and(fetch, ~pf)

                    @pl.when(pf)
                    def _(c=c):
                        w_out_tab.wait_load(uniq_ref[0, i, c],
                                            out_uniq.at[buf, pl.ds(c, 1)],
                                            sem_pf.at[buf])

                @pl.when(fetch)
                def _(c=c):
                    w_out_tab.wait_load(uniq_ref[0, i, c],
                                        out_uniq.at[buf, pl.ds(c, 1)], sem)
            if u_pad > M:
                out_uniq[buf, pl.ds(M, u_pad - M), :] = jnp.zeros(
                    (u_pad - M, d), out_uniq.dtype)

            # ---- overlap: start streaming tile i+1's unique rows into the
            # other half while this tile's GEMM groups run; rows colliding
            # with this tile's write-back set stay un-prefetched (the wait
            # site recomputes the same predicate and sync-loads them) ----
            if prefetch:
                nxt = jnp.minimum(i + 1, nt - 1)
                for c in range(M):
                    @pl.when(was_prefetched(i + 1, c))
                    def _(c=c):
                        w_out_tab.start_load(
                            uniq_ref[0, nxt, c],
                            out_uniq.at[1 - buf, pl.ds(c, 1)],
                            sem_pf.at[1 - buf])

            # GEMM groups of G windows: deltas land in the VMEM ring /
            # out_uniq between groups, bounding staleness to G windows
            # while the HBM traffic stays once-per-tile
            def fused_group(base, w0, wn):
                # gather the group's context rows from the (fresh) ring
                for w in range(wn):
                    _gather_window_ctx(ring, ctx_tile, base + w, w * k,
                                       w_f=w_f, r=rt, length=length, L=L)
                _zero_rows(ctx_tile, wn * k, gk_pad)

                # expand the group's slots from the (fresh) compacted rows
                for sj in range(wn * m):
                    col = scat_ref[0, i, w0 * m + sj]
                    out_exp[pl.ds(sj, 1), :] = out_uniq[buf,
                                                        pl.ds(col, 1), :]
                _zero_rows(out_exp, wn * m, gm_pad)

                # two MXU-shaped GEMMs with a block-diagonal mask (window
                # w's context rows pair only with window w's slots)
                ri = jax.lax.iota(jnp.int32, gk_pad)
                jr = jax.lax.rem(ri, k)
                win_r = jax.lax.div(ri, k)
                offs_arr = jnp.where(jr < w_f, jr - w_f, jr - w_f + 1)
                p_arr = base + win_r + offs_arr
                row_valid = ((p_arr >= 0) & (p_arr < length)
                             & (base + win_r < length) & (ri < wn * k))
                ci = jax.lax.iota(jnp.int32, gm_pad)
                win_c = jax.lax.div(ci, m)
                col_valid = (base + win_c < length) & (ci < wn * m)
                label = (jax.lax.rem(ci, m) == 0).astype(jnp.float32)
                label = jnp.broadcast_to(label[None, :], (gk_pad, gm_pad))
                mask = (row_valid[:, None] & col_valid[None, :]
                        & (win_r[:, None] == win_c[None, :]))

                d_ctx, d_out = _window_update(ctx_tile[...], out_exp[...],
                                              label, mask, lr)

                # apply context deltas (repeats accumulate in slot order)
                for w in range(wn):
                    _scatter_window_ctx(ring, d_ctx, base + w, w * k,
                                        w_f=w_f, r=rt, L=L)

                # compact output deltas through the scatter map (invalid
                # slots carry zero gradient)
                for sj in range(wn * m):
                    col = scat_ref[0, i, w0 * m + sj]
                    out_uniq[buf, pl.ds(col, 1), :] = (
                        out_uniq[buf, pl.ds(col, 1), :] + d_out[sj:sj + 1, :])

            for b in range((tile + G - 1) // G):
                w0 = b * G
                wn = min(G, tile - w0)         # windows in this group
                base = t0 + w0

                @pl.when(base < length)
                def _(base=base, w0=w0, wn=wn):
                    # ring advance for the group: window 0 follows the exact
                    # sequential store-then-load order (its evictee is
                    # complete); the remaining loads batch up front and
                    # their evictees are stored after the GEMM below, once
                    # this group's context updates have landed
                    advance_window(base)
                    for w in range(1, wn):
                        q = base + w + w_f

                        @pl.when(q < length)
                        def _(q=q):
                            load_ring(q)

                    fused_group(base, w0, wn)

                    for w in range(1, wn):
                        q = base + w + w_f
                        p = q - r_seq

                        @pl.when(jnp.logical_and(q < length, p >= 0))
                        def _(p=p):
                            store_ring(p)

            # write each unique row back once per tile
            for c in range(M):
                @pl.when(c < u)
                def _(c=c):
                    w_out_tab.start_store(out_uniq.at[buf, pl.ds(c, 1)],
                                          uniq_ref[0, i, c], sem)
            for c in range(M):
                @pl.when(c < u)
                def _(c=c):
                    w_out_tab.wait_store(out_uniq.at[buf, pl.ds(c, 1)],
                                         uniq_ref[0, i, c], sem)
        return 0

    def guarded_tile(i, c):
        @pl.when(i * tile < length)
        def _():
            tile_step(i, c)
        return 0

    jax.lax.fori_loop(0, nt, guarded_tile, 0)

    # --- flush surviving ring entries (increasing position order); the
    # r-distance store schedule leaves the same survivors as `_kernel` ---
    def flush(kk, _):
        p = length - r_seq + kk

        @pl.when(jnp.logical_and(p >= 0, p < length))
        def _():
            store_ring(p)
        return 0

    jax.lax.fori_loop(0, r_seq, flush, 0, unroll=True)


# ---------------------------------------------------------------------------
# Host-side entry points
# ---------------------------------------------------------------------------

def fullw2v_pallas(
    w_in: jax.Array,     # (V, d) f32
    w_out: jax.Array,    # (V, d) f32
    tokens: jax.Array,   # (S, L) int32
    negs: jax.Array,     # (S, L, N) int32
    lengths: jax.Array,  # (S,) int32
    lr: jax.Array,       # scalar f32
    w_f: int,
    interpret: bool = False,
    pipeline: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One FULL-W2V training pass over a batch of sentences."""
    S, L = tokens.shape
    n_neg = negs.shape[-1]
    V, d = w_in.shape
    assert d % LANE == 0, f"embedding dim {d} must be a multiple of {LANE}"
    r = 2 * w_f + 1
    r_pad = _round_up(r, SUBLANE)
    k_pad = _round_up(2 * w_f, SUBLANE)
    m_pad = _round_up(n_neg + 1, SUBLANE)

    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))

    grid = (S,)
    if pipeline:
        kernel = functools.partial(_kernel_pipelined, w_f=w_f, n_neg=n_neg)
        scratch = [
            pltpu.VMEM((r_pad, d), jnp.float32),
            pltpu.VMEM((k_pad, d), jnp.float32),
            pltpu.VMEM((2, m_pad, d), jnp.float32),   # double buffer
            pltpu.SemaphoreType.DMA,                   # ring/stores
            pltpu.SemaphoreType.DMA((2,)),             # per-half prefetch
        ]
    else:
        kernel = functools.partial(_kernel, w_f=w_f, n_neg=n_neg)
        scratch = [
            pltpu.VMEM((r_pad, d), jnp.float32),
            pltpu.VMEM((k_pad, d), jnp.float32),
            pltpu.VMEM((m_pad, d), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, L, n_neg), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda s: (s,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, d), w_in.dtype),
            jax.ShapeDtypeStruct((V, d), w_out.dtype),
        ],
        scratch_shapes=scratch,
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(tokens, negs, lengths, lr_arr, w_in, w_out)
    return out[0], out[1]


def fullw2v_pallas_tiled(
    w_in: jax.Array,     # (V, d) f32
    w_out: jax.Array,    # (V, d) f32
    tokens: jax.Array,   # (S, L) int32
    negs: jax.Array,     # (S, L, N) int32
    lengths: jax.Array,  # (S,) int32
    lr: jax.Array,       # scalar f32
    w_f: int,
    tile: int,
    uniq: jax.Array,     # (S, nt, T*(N+1)) int32 — from plan_tiles
    scatter: jax.Array,  # (S, nt, T*(N+1)) int32
    ucount: jax.Array,   # (S, nt) int32
    strict: jax.Array,   # (S, nt) int32
    gemm_windows: int = 0,   # windows per GEMM group; 0 -> min(tile, 4)
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Window-tile batched FULL-W2V pass (DESIGN.md §4). The tile schedule
    must come from `repro.data.batching.plan_tiles(tokens, negs, lengths,
    tile)` for the same batch. ``gemm_windows`` bounds intra-tile value
    staleness: output/context deltas are applied in VMEM between GEMM
    groups, so only ~G windows ever read stale values while HBM traffic
    stays once-per-tile."""
    S, L = tokens.shape
    n_neg = negs.shape[-1]
    V, d = w_in.shape
    assert d % LANE == 0, f"embedding dim {d} must be a multiple of {LANE}"
    assert tile >= 1
    G = resolve_gemm_windows(tile, gemm_windows)
    m = n_neg + 1
    nt = uniq.shape[1]
    M = tile * m
    assert uniq.shape == (S, nt, M), (uniq.shape, (S, nt, M))
    assert scatter.shape == (S, nt, M)
    assert nt == -(-L // tile)
    dims = tiled_scratch_rows(tile, w_f, n_neg, G)

    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))

    kernel = functools.partial(_kernel_tiled, w_f=w_f, n_neg=n_neg,
                               tile=tile, gemm_windows=G)
    scratch = [
        pltpu.VMEM((dims["ring"], d), jnp.float32),
        pltpu.VMEM((dims["ctx_tile"], d), jnp.float32),  # one GEMM group
        pltpu.VMEM((1, dims["out_uniq"], d), jnp.float32),
        pltpu.VMEM((dims["out_exp"], d), jnp.float32),   # one GEMM group
        pltpu.VMEM((dims["ctx_win"], d), jnp.float32),   # strict path
        pltpu.VMEM((dims["out_win"], d), jnp.float32),   # strict path
        pltpu.SemaphoreType.DMA,
    ]
    out = pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, L), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, L, n_neg), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda s: (s,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt, M), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt, M), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, d), w_in.dtype),
            jax.ShapeDtypeStruct((V, d), w_out.dtype),
        ],
        scratch_shapes=scratch,
        input_output_aliases={8: 0, 9: 1},
        interpret=interpret,
    )(tokens, negs, lengths, lr_arr, uniq, scatter, ucount, strict,
      w_in, w_out)
    return out[0], out[1]


def fullw2v_pallas_tiled_fused(
    hot_in: jax.Array,   # (hot, d) f32 — replicated hot head
    hot_out: jax.Array,  # (hot, d) f32
    got_in: jax.Array,   # (R, d) f32 — gathered cold block (request order)
    got_out: jax.Array,  # (R, d) f32
    tokens: jax.Array,   # (S, L) int32 — working-table ids (< hot + R)
    negs: jax.Array,     # (S, L, N) int32
    lengths: jax.Array,  # (S,) int32
    lr: jax.Array,       # scalar f32
    w_f: int,
    tile: int,
    uniq: jax.Array,     # (S, nt, T*(N+1)) int32 — from plan_tiles
    scatter: jax.Array,  # (S, nt, T*(N+1)) int32
    ucount: jax.Array,   # (S, nt) int32
    strict: jax.Array,   # (S, nt) int32
    gemm_windows: int = 0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The window-tiled pass on a *split* vocab-sharded working table
    (DESIGN.md §8 fused gather): the hot replica and the gathered cold
    block stay separate HBM buffers — every token/negative/plan id below
    ``hot`` streams from ``hot_*``, the rest from ``got_*`` at ``id -
    hot`` — and the tile fetch stage is double-buffered so tile i+1's
    cold-row DMAs overlap tile i's window GEMMs. Semantics are identical
    to running :func:`fullw2v_pallas_tiled` on ``concat(hot, got)`` and
    splitting the result."""
    S, L = tokens.shape
    n_neg = negs.shape[-1]
    hot, d = hot_in.shape
    r_width = got_in.shape[0]
    assert d % LANE == 0, f"embedding dim {d} must be a multiple of {LANE}"
    assert hot >= 1
    assert got_in.shape == got_out.shape == (r_width, d)
    assert hot_out.shape == (hot, d)
    assert tile >= 1
    G = resolve_gemm_windows(tile, gemm_windows)
    m = n_neg + 1
    nt = uniq.shape[1]
    M = tile * m
    assert uniq.shape == (S, nt, M), (uniq.shape, (S, nt, M))
    assert scatter.shape == (S, nt, M)
    assert nt == -(-L // tile)
    dims = tiled_scratch_rows(tile, w_f, n_neg, G)

    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))

    kernel = functools.partial(_kernel_tiled, w_f=w_f, n_neg=n_neg,
                               tile=tile, gemm_windows=G, hot_rows=hot,
                               prefetch=True)
    scratch = [
        pltpu.VMEM((dims["ring"], d), jnp.float32),
        pltpu.VMEM((dims["ctx_tile"], d), jnp.float32),
        pltpu.VMEM((2, dims["out_uniq"], d), jnp.float32),  # double buffer
        pltpu.VMEM((dims["out_exp"], d), jnp.float32),
        pltpu.VMEM((dims["ctx_win"], d), jnp.float32),
        pltpu.VMEM((dims["out_win"], d), jnp.float32),
        pltpu.SemaphoreType.DMA,                             # strict/ring
        pltpu.SemaphoreType.DMA((2,)),                       # per-half
    ]
    out = pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, L), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, L, n_neg), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda s: (s,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt, M), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt, M), lambda s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, nt), lambda s: (s, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hot, d), hot_in.dtype),
            jax.ShapeDtypeStruct((hot, d), hot_out.dtype),
            jax.ShapeDtypeStruct((r_width, d), got_in.dtype),
            jax.ShapeDtypeStruct((r_width, d), got_out.dtype),
        ],
        scratch_shapes=scratch,
        input_output_aliases={8: 0, 9: 1, 10: 2, 11: 3},
        interpret=interpret,
    )(tokens, negs, lengths, lr_arr, uniq, scatter, ucount, strict,
      hot_in, hot_out, got_in, got_out)
    return out[0], out[1], out[2], out[3]
