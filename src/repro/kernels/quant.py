"""Quantized table storage primitives (DESIGN.md §11).

FULL-W2V's thesis is bytes-per-update: every level of the paper's reuse
hierarchy (registers → shared memory → HBM; VMEM → HBM → ICI here) wins by
moving fewer bytes per touched row. Storage precision is one level deeper:
``bfloat16`` halves and ``int8`` (per-row absmax scales) quarters the bytes
per row — in HBM, in the §8 cold-row exchange, and in split checkpoints —
while the update math stays f32 (Ji et al., PAPERS.md: SGNS quality
tolerates reduced-precision *storage* when accumulation doesn't).

Two rounding modes, used at different seams:

* **Nearest** (deterministic) — initialization, checkpoint restore, and
  the *transport* leg of the mixed exchange (requester→owner write-back).
  Unbiased rounding buys nothing there because the value is re-rounded at
  the storage seam anyway.
* **Stochastic** (keyed) — the *storage* seam after each update. Rounding
  to nearest every step would bias small updates (lr·grad below half an
  ulp always rounds away); stochastic rounding keeps the expected table
  equal to the f32 trajectory. Keys derive from the PR 4 counter
  randomness — ``(seed, epoch, batch_index)`` through a domain-separation
  tag — so every run, any worker count, and every chaos-recovery replay
  draws the identical rounding noise: runs stay bit-deterministic and the
  §9 digest checks keep passing.

int8 rows carry a per-row f32 scale ``max|row| / 127``; the row's absmax
element always encodes to exactly ±127 (``floor(127 + u) = 127`` for
``u ∈ [0, 1)``), so decode→re-encode of an untouched row is a fixed point
and quantized storage does not drift between touches.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# domain-separation tags, disjoint from data/batching.py's subsample
# (0x5B5A) and negatives (0x4E45) tags
_ROUND_TAG = 0x5254          # "RT" — round-to-storage key family
TAG_HOT_IN, TAG_HOT_OUT = 0, 1
TAG_COLD_IN, TAG_COLD_OUT = 2, 3
TAG_FULL_IN, TAG_FULL_OUT = 4, 5     # master-copy / replicated full tables

STORAGE_DTYPES = ("float32", "bfloat16", "int8")


def round_key(seed: int, epoch: int, batch_index: int) -> np.ndarray:
    """uint32[2] threefry key for one batch's storage rounding — a pure
    function of the same counters that key subsampling and negatives, so
    the rounding noise replays bit-identically across worker counts and
    chaos recoveries."""
    ss = np.random.SeedSequence([seed, _ROUND_TAG, epoch, batch_index])
    return ss.generate_state(2, np.uint32)


# ---------------------------------------------------------------------------
# bfloat16: truncate-with-random-carry stochastic rounding
# ---------------------------------------------------------------------------

def bf16_nearest(x: jax.Array) -> jax.Array:
    """Round-to-nearest-even f32 → bf16 (init / restore / transport)."""
    return x.astype(jnp.bfloat16)


def bf16_stochastic(x: jax.Array, key: jax.Array) -> jax.Array:
    """Stochastically round f32 → bf16: add uniform noise to the 16 bits
    about to be truncated, then truncate. P(round up) equals the truncated
    fraction, so E[result] = x; values already representable in bf16 (all
    low bits zero) are preserved exactly — no carry can reach bit 16."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.bits(key, x.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    hi = ((bits + noise) >> 16).astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(hi, jnp.bfloat16)


# ---------------------------------------------------------------------------
# int8 with per-row scales
# ---------------------------------------------------------------------------

def int8_scale(x: jax.Array) -> jax.Array:
    """Per-row absmax scale ``max|row| / 127`` (all-zero rows get 1.0 so
    decode stays a plain multiply)."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    return jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)


def int8_nearest(x: jax.Array,
                 scale: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Deterministic f32 → (int8, scale) encode, round-to-nearest."""
    if scale is None:
        scale = int8_scale(x)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def int8_stochastic(x: jax.Array, key: jax.Array,
                    scale: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Stochastic f32 → (int8, scale) encode: ``floor(x/scale + u)`` with
    ``u ~ U[0, 1)`` rounds up with probability equal to the fractional
    part — unbiased in expectation over keyed draws."""
    if scale is None:
        scale = int8_scale(x)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = jnp.clip(jnp.floor(x / scale[..., None] + u), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    """(int8, per-row scale) → f32."""
    return q.astype(jnp.float32) * scale[..., None]


# ---------------------------------------------------------------------------
# dtype-generic storage codec (the seam ops.step and the trainer use)
# ---------------------------------------------------------------------------

def decode(payload: jax.Array, scale: Optional[jax.Array],
           dtype: str) -> jax.Array:
    """Storage → f32 working values."""
    if dtype == "int8":
        return int8_decode(payload, scale)
    if dtype == "float32":
        return payload
    return payload.astype(jnp.float32)


def encode_nearest(x: jax.Array, dtype: str
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """f32 → (payload, scale-or-None), deterministic nearest rounding."""
    if dtype == "float32":
        return x, None
    if dtype == "bfloat16":
        return bf16_nearest(x), None
    return int8_nearest(x)


def encode_stochastic(x: jax.Array, dtype: str, key: jax.Array,
                      tag: int) -> Tuple[jax.Array, Optional[jax.Array]]:
    """f32 → (payload, scale-or-None), keyed stochastic rounding; ``tag``
    domain-separates the tables sharing one batch key (TAG_*)."""
    if dtype == "float32":
        return x, None
    k = jax.random.fold_in(key, tag)
    if dtype == "bfloat16":
        return bf16_stochastic(x, k), None
    return int8_stochastic(x, k)
