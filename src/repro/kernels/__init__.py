"""FULL-W2V kernel package (the paper's one custom-kernel hot spot).

Layout: ``fullw2v.py`` (Pallas TPU kernels) + ``ref.py`` (jnp oracles) +
``registry.py`` (engine API: backend descriptors, ``StepInputs``,
resolution) + ``ops.py`` (backend registrations and the single public
``sgns_update`` dispatch entry point). Import ``repro.kernels.ops`` to
train; query ``repro.kernels.registry`` for the available backends.
"""
