"""FULL-W2V kernel package (the paper's one custom-kernel hot spot).

Layout: ``fullw2v.py`` (Pallas TPU kernels) + ``ref.py`` (jnp oracles) +
``registry.py`` (engine API: backend descriptors, ``StepInputs``,
resolution) + ``ops.py`` (backend registrations and the single public
``step(tables, step, cfg, backend)`` dispatch entry point) +
``tables.py``/``quant.py`` (``TableSpec`` storage dtypes, DESIGN.md §11). Import ``repro.kernels.ops`` to
train; query ``repro.kernels.registry`` for the available backends.
"""
