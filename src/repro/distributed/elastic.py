"""Elastic scaling: rebuild the largest valid mesh from surviving devices.

After a node failure the job restarts with fewer devices; checkpoints are
mesh-agnostic (host arrays + reshard-on-load), so the only decision is the
new mesh shape. Policy: keep the `model` axis as requested (TP degree is an
algorithmic choice), shrink `data`(, `pod`) to the largest multiple that
fits the surviving device count.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(n_devices: int, model_parallel: int = 16,
              pods: Optional[int] = None) -> MeshPlan:
    """Largest (pod?, data, model) mesh with `model_parallel` TP that fits
    n_devices. Falls back to smaller TP if n_devices < model_parallel."""
    tp = model_parallel
    while tp > 1 and n_devices % tp != 0:
        tp //= 2
    rest = n_devices // tp
    if pods and pods > 1 and rest % pods == 0 and rest // pods >= 1:
        return MeshPlan((pods, rest // pods, tp), ("pod", "data", "model"))
    return MeshPlan((rest, tp), ("data", "model"))


def build(plan: MeshPlan):
    return jax.make_mesh(plan.shape, plan.axes)


def degrade_sequence(start_devices: int, model_parallel: int,
                     failures: List[int]) -> List[MeshPlan]:
    """The sequence of meshes a job walks through as `failures[i]` devices
    die at event i — used by tests and capacity planning."""
    out = []
    n = start_devices
    for f in failures:
        n = max(n - f, 1)
        out.append(plan_mesh(n, model_parallel))
    return out
