from repro.distributed.sharding import (
    axis_rules,
    constrain,
    current_rules,
    param_shardings,
)

__all__ = ["axis_rules", "constrain", "current_rules", "param_shardings"]
