from repro.distributed.sharding import (
    axis_rules,
    constrain,
    current_rules,
    param_shardings,
    vocab_shard_sharding,
)
from repro.distributed.vocab_placement import (
    VocabExchange,
    VocabPlacement,
    plan_exchange,
)

__all__ = ["axis_rules", "constrain", "current_rules", "param_shardings",
           "vocab_shard_sharding", "VocabExchange", "VocabPlacement",
           "plan_exchange"]
