"""Int8 error-feedback gradient/delta compression for cross-pod sync.

Cross-pod links (DCN) are an order of magnitude slower than intra-pod ICI;
the W2V Hogwild averaging and any cross-pod gradient reduction optionally
compress deltas to int8 with per-tensor scale and an error-feedback
accumulator (the residual re-enters the next round, so the scheme is
unbiased in the long run — standard EF-SGD).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any    # pytree like the compressed tree (f32)


def ef_init(tree: Any) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree))


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8, scale). Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree: Any, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (quantized tree, scales tree, new EF state).

    The value transmitted is quantize(x + residual); the quantization error
    is carried into the next round's residual."""
    def one(x, r):
        target = x.astype(jnp.float32) + r
        q, s = quantize(target)
        err = target - dequantize(q, s)
        return q, s, err

    qs, ss, errs = [], [], []
    leaves, treedef = jax.tree.flatten(tree)
    for x, r in zip(leaves, jax.tree.leaves(ef.residual)):
        q, s, e = one(x, r)
        qs.append(q)
        ss.append(s)
        errs.append(e)
    unf = lambda ls: jax.tree.unflatten(treedef, ls)
    return unf(qs), unf(ss), EFState(residual=unf(errs))


def decompress_tree(qtree: Any, stree: Any) -> Any:
    return jax.tree.map(dequantize, qtree, stree)


def compressed_mean_bytes(tree: Any) -> Tuple[int, int]:
    """(raw f32 bytes, compressed bytes) — reported by benchmarks."""
    raw = sum(x.size * 4 for x in jax.tree.leaves(tree))
    comp = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return raw, comp
