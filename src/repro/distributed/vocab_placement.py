"""Vocabulary placement: replicated hot head + mesh-sharded cold tail.

FULL-W2V's reuse hierarchy keeps hot rows near the compute (registers /
shared memory in the paper; ring buffer / tile dedup here) and spills cold
rows to HBM. This module extends the same hierarchy one level up — across
the *mesh*: the Zipf-hot head of the vocabulary (top-K rows by corpus
frequency, covering ~90% of token occurrences) is replicated on every
device, while the cold tail is sharded over the ``data`` axis, so trainable
vocabulary scales with device count instead of being capped by one device's
HBM (DESIGN.md §8; the hybrid replicate/shard strategy of Ji et al.,
arXiv:1604.04661).

Two host-side artifacts:

* :class:`VocabPlacement` — the static placement: hot size, shard count,
  striped ownership of cold rows, and the split/merge permutations between
  the replicated ``(V, d)`` layout and the ``hot + sharded-cold`` layout.
* :func:`plan_exchange` — the per-batch exchange plan: for each mesh shard,
  the *distinct* cold rows its sentences touch (the same first-seen dedup
  rule ``plan_tiles`` applies per window tile, applied per shard —
  ``data.batching.first_seen_unique``) plus token/negative/plan index
  arrays remapped into the shard's compact working-table space. The device
  step then all-gathers O(distinct rows), never O(V).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# Default Zipf coverage of the replicated hot head: the smallest frequency-
# ranked prefix whose occurrence mass reaches this fraction is replicated.
VOCAB_HOT_COVERAGE = 0.9

# Per-shard exchange lists are padded up to a multiple of this, so the jit
# cache sees a handful of request widths per run instead of one per batch.
_REQUEST_PAD = 64

# Per-owner capacity buckets are padded up to a multiple of this. Buckets
# are ~R/n_shards entries each (modulo striping balances them), so a finer
# granule than _REQUEST_PAD keeps the all_to_all padding overhead small
# while still bounding the number of distinct jit cache keys.
_BUCKET_PAD = 8


@dataclasses.dataclass(frozen=True)
class VocabPlacement:
    """Static hot/cold placement of a ``(V, d)`` embedding table.

    Rows ``[0, hot)`` (the vocabulary is frequency-sorted by construction,
    ``data.vocab.Vocab.build``) are replicated on every shard. Cold rows
    ``[hot, V)`` are striped over ``n_shards``: cold index ``c = id - hot``
    lives on shard ``c % n_shards`` at local row ``c // n_shards`` — modulo
    striping, so the Zipf tail's residual skew spreads evenly instead of
    loading shard 0 with the warmest cold rows.
    """

    vocab_size: int
    hot: int
    n_shards: int

    def __post_init__(self):
        if not (1 <= self.hot <= self.vocab_size):
            raise ValueError(
                f"hot head must satisfy 1 <= hot <= V; got hot={self.hot}, "
                f"V={self.vocab_size}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")

    # -- derived sizes -------------------------------------------------------
    @property
    def cold(self) -> int:
        """Real cold rows (``V - hot``)."""
        return self.vocab_size - self.hot

    @property
    def cold_pad(self) -> int:
        """Cold rows padded up to a multiple of ``n_shards`` (>= n_shards,
        so the sharded table is never zero-sized)."""
        n = self.n_shards
        return max(n, -(-self.cold // n) * n)

    @property
    def cold_per_shard(self) -> int:
        """Local cold rows per shard."""
        return self.cold_pad // self.n_shards

    @property
    def rows_per_device(self) -> int:
        """Embedding rows resident per device: hot replica + cold shard."""
        return self.hot + self.cold_per_shard

    # -- construction --------------------------------------------------------
    @classmethod
    def plan(cls, counts: np.ndarray, n_shards: int,
             hot_frac: float = 0.0,
             coverage: float = VOCAB_HOT_COVERAGE) -> "VocabPlacement":
        """Choose the hot head for a frequency-sorted vocabulary.

        ``hot_frac > 0`` pins the head to ``round(hot_frac * V)`` rows;
        otherwise the head is the smallest prefix whose occurrence mass
        reaches ``coverage`` (under Zipf that is a small fraction of V
        covering ~90% of token traffic). The head is clamped to ``[1,
        V - 1]`` so there is always at least one cold row to shard.
        """
        counts = np.asarray(counts)
        v = int(counts.size)
        if v < 2:
            raise ValueError(f"vocab too small to shard (V={v})")
        if hot_frac > 0.0:
            hot = int(round(hot_frac * v))
        else:
            mass = np.cumsum(counts, dtype=np.float64)
            total = float(mass[-1]) or 1.0
            hot = int(np.searchsorted(mass, coverage * total) + 1)
        hot = max(1, min(hot, v - 1))
        return cls(vocab_size=v, hot=hot, n_shards=int(n_shards))

    # -- ownership -----------------------------------------------------------
    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning shard per id (-1 for hot/replicated ids)."""
        ids = np.asarray(ids)
        return np.where(ids >= self.hot, (ids - self.hot) % self.n_shards,
                        -1)

    def local_row(self, ids: np.ndarray) -> np.ndarray:
        """Local row index on the owning shard (0 for hot ids)."""
        ids = np.asarray(ids)
        return np.where(ids >= self.hot, (ids - self.hot) // self.n_shards,
                        0)

    def _perm(self) -> np.ndarray:
        """Padded cold index -> position in the shard-major cold table."""
        ci = np.arange(self.cold_pad)
        return (ci % self.n_shards) * self.cold_per_shard + \
            (ci // self.n_shards)

    # -- layout conversion ---------------------------------------------------
    def split(self, full: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """``(V, d)`` table -> (hot replica ``(hot, d)``, shard-major cold
        table ``(cold_pad, d)``; rows ``[i*cps, (i+1)*cps)`` belong to shard
        i). Padding rows are zero. Exact inverse of :meth:`merge`.

        Works on any trailing shape — including 1-D ``(V,)`` vectors, which
        is how int8 per-row scales colocate with their cold shards: split
        with the *same* row permutation as the cold rows themselves, so
        ``scale[i]`` always lives on the shard serving ``cold[i]``."""
        full = np.asarray(full)
        if full.shape[0] != self.vocab_size:
            raise ValueError(f"table has {full.shape[0]} rows, placement "
                             f"expects V={self.vocab_size}")
        cold_arr = np.zeros((self.cold_pad,) + full.shape[1:], full.dtype)
        ci = np.arange(self.cold)
        cold_arr[self._perm()[:self.cold]] = full[self.hot + ci]
        return full[:self.hot].copy(), cold_arr

    def merge(self, hot: np.ndarray, cold: np.ndarray) -> np.ndarray:
        """Reassemble the replicated ``(V, d)`` table from split parts."""
        hot, cold = np.asarray(hot), np.asarray(cold)
        if hot.shape[0] != self.hot or cold.shape[0] != self.cold_pad:
            raise ValueError(
                f"split shapes ({hot.shape[0]}, {cold.shape[0]}) do not "
                f"match placement (hot={self.hot}, cold_pad={self.cold_pad})")
        full = np.empty((self.vocab_size,) + hot.shape[1:], hot.dtype)
        full[:self.hot] = hot
        full[self.hot:] = cold[self._perm()[:self.cold]]
        return full

    # -- checkpoint metadata -------------------------------------------------
    def to_extra(self) -> Dict[str, int]:
        """Serializable placement metadata stored with split checkpoints."""
        return {"vocab_size": self.vocab_size, "hot": self.hot,
                "n_shards": self.n_shards}

    @classmethod
    def from_extra(cls, extra: Dict[str, Any]) -> "VocabPlacement":
        """Rebuild the placement a checkpoint was written under."""
        return cls(vocab_size=int(extra["vocab_size"]),
                   hot=int(extra["hot"]), n_shards=int(extra["n_shards"]))


@dataclasses.dataclass
class VocabExchange:
    """One batch's exchange plan: remapped index arrays + request lists.

    ``tokens``/``negs`` (and ``plan_uniq`` when the batch carries a window-
    tile plan) are rewritten into each shard's *working-table* index space:
    hot ids keep their global index (the hot head is the working table's
    prefix), and the shard's r-th distinct cold id maps to ``hot + r``. The
    device step reassembles exactly this working table — hot replica rows
    followed by the gathered cold rows, in request order — so the kernels
    run unchanged on a compact ``(hot + R, d)`` table.

    ``cold_ids[s]`` lists shard s's distinct cold ids (first-seen order,
    -1 padded to the common width R).

    ``bucket_ids``/``bucket_pos`` re-sort each request list into per-owner
    *capacity buckets* for the request-exact ``all_to_all`` exchange:
    ``bucket_ids[s, o]`` holds the subset of ``cold_ids[s]`` owned by shard
    ``o`` (-1 padded to the common capacity C), and ``bucket_pos[s, o]``
    each id's position within shard s's gathered working block (so the
    served rows scatter straight back into request order; pad slots point
    one past the end, R, and are dropped). Because ownership is a partition
    of the request list, ``sum_o count(s, o) == n_distinct[s]`` and the
    positions of a shard's valid slots are a permutation of
    ``range(n_distinct[s])``.
    """

    placement: VocabPlacement
    tokens: np.ndarray                     # (S, L) int32, remapped
    negs: np.ndarray                       # (S, L, N) int32, remapped
    lengths: np.ndarray                    # (S,) int32 (unchanged)
    cold_ids: np.ndarray                   # (n_shards, R) int32, -1 padded
    n_distinct: List[int]                  # real request count per shard
    bucket_ids: np.ndarray = None          # (n, n, C) int32, -1 padded
    bucket_pos: np.ndarray = None          # (n, n, C) int32, R padded
    plan_uniq: Optional[np.ndarray] = None     # remapped tile plan rows
    plan_scatter: Optional[np.ndarray] = None  # (unchanged)
    plan_ucount: Optional[np.ndarray] = None
    plan_strict: Optional[np.ndarray] = None
    # frontend extras (DESIGN.md §12), remapped like tokens/negs with -1
    # (no doc / bag pad) preserved. Extras occupy the zero-count table
    # tail, so they are always cold rows and always ride the exchange.
    docs: Optional[np.ndarray] = None          # (S,) static ctx rows
    bags: Optional[np.ndarray] = None          # (S, L, B) member rows

    @property
    def request_width(self) -> int:
        """R — padded distinct-cold-rows-per-shard this batch."""
        return int(self.cold_ids.shape[1])

    @property
    def bucket_capacity(self) -> int:
        """C — padded per-(requester, owner) bucket width this batch."""
        return int(self.bucket_ids.shape[2])

    @property
    def bucket_real(self) -> int:
        """Real (unpadded) bucket entries across all shards — equals
        ``sum(n_distinct)`` since ownership partitions each request list."""
        return int((self.bucket_ids >= 0).sum())

    @property
    def bucket_occupancy(self) -> float:
        """Fill fraction of the padded bucket tensor: real entries over
        ``n² · C`` slots. The complement is pure padding overhead that the
        all_to_all still moves; ``benchmarks/bench_memory.py`` tracks it."""
        return self.bucket_real / float(self.bucket_ids.size or 1)

    @staticmethod
    def row_bytes(dim: int, dtype: str = "float32") -> int:
        """Wire bytes per exchanged row in storage dtype ``dtype``
        (DESIGN.md §11): f32 ``4d``, bf16 ``2d``, int8 ``d + 4`` — the
        quantized payload plus its per-row f32 scale, which travels in a
        sibling ``all_to_all`` on the exact path."""
        itemsize = {"float32": 4, "bfloat16": 2, "int8": 1}[dtype]
        return dim * itemsize + (4 if dtype == "int8" else 0)

    def bytes_exchanged(self, dim: int, itemsize: int = 4,
                        dtype: Optional[str] = None) -> int:
        """Ideal per-step *payload* volume summed over the mesh: each
        distinct cold row crosses the interconnect twice per table (value
        gather + update write-back), for both ``w_in`` and ``w_out`` —
        O(distinct rows), never O(V). ``dtype`` prices the rows in their
        storage precision (overrides ``itemsize``)."""
        row = self.row_bytes(dim, dtype) if dtype else dim * itemsize
        return sum(self.n_distinct) * row * 2 * 2

    def bytes_device_dense(self, dim: int, itemsize: int = 4) -> int:
        """Per-device bytes the PR 5 *dense* exchange moved: all_gather +
        psum_scatter materialize every shard's full padded request list on
        every device — ``n · R`` rows per direction per table, an n-fold
        constant over the payload (DESIGN.md §8). Always f32: the dense
        reference path dequantizes *before* its collectives (psum_scatter
        must sum in f32), so quantized storage buys it nothing on the
        wire."""
        n = self.placement.n_shards
        return n * self.request_width * dim * itemsize * 2 * 2

    def bytes_device_exact(self, dim: int, itemsize: int = 4,
                           dtype: Optional[str] = None) -> int:
        """Per-device bytes of the request-exact bucketed ``all_to_all``:
        ``n · C ≈ R`` rows per direction per table (capacity padding is the
        only slack — bounded by ``bucket_occupancy``), so per-device
        traffic is O(distinct · d) regardless of mesh size. ``dtype``
        prices the rows in their storage precision — the exact path moves
        rows *quantized* (int8 payload + f32 scale, or bf16), which is
        where the §11 2×/4× exchange-byte reduction lands."""
        n = self.placement.n_shards
        row = self.row_bytes(dim, dtype) if dtype else dim * itemsize
        return n * self.bucket_capacity * row * 2 * 2

    def step_inputs(self, lr) -> "Any":
        """Lift onto the device as a vocab-sharded ``StepInputs``."""
        import jax.numpy as jnp

        from repro.kernels.registry import StepInputs
        kw = {}
        if self.plan_uniq is not None:
            kw = dict(plan_uniq=jnp.asarray(self.plan_uniq),
                      plan_scatter=jnp.asarray(self.plan_scatter),
                      plan_ucount=jnp.asarray(self.plan_ucount),
                      plan_strict=jnp.asarray(self.plan_strict))
        if self.docs is not None:
            kw["static_ctx"] = jnp.asarray(self.docs)
        if self.bags is not None:
            kw["bags"] = jnp.asarray(self.bags)
        return StepInputs(tokens=jnp.asarray(self.tokens),
                          negs=jnp.asarray(self.negs),
                          lengths=jnp.asarray(self.lengths),
                          lr=jnp.asarray(lr, jnp.float32),
                          cold_ids=jnp.asarray(self.cold_ids),
                          bucket_ids=jnp.asarray(self.bucket_ids),
                          bucket_pos=jnp.asarray(self.bucket_pos), **kw)


def plan_exchange(batch, placement: VocabPlacement) -> VocabExchange:
    """Build the per-shard row-exchange plan for one host batch.

    For each of the ``n_shards`` sentence shards (contiguous row blocks of
    the batch, matching the ``P("data")`` sharding the trainer applies),
    collect the distinct cold ids its tokens, negatives, and tile-plan rows
    touch — first-seen order, the ``plan_tiles`` dedup rule lifted from one
    window tile to a whole shard — and remap every index array into the
    shard's compact working-table space.
    """
    from repro.data.batching import first_seen_unique

    n = placement.n_shards
    hot = placement.hot
    s_total = batch.tokens.shape[0]
    if s_total % n != 0:
        raise ValueError(
            f"batch of {s_total} sentences does not shard over {n} devices; "
            f"set cfg.sentences_per_batch to a multiple of the data axis")
    per = s_total // n

    tokens = batch.tokens.copy()
    negs = batch.negs.copy()
    plan = batch.plan
    uniq = plan.uniq.copy() if plan is not None else None
    docs = getattr(batch, "docs", None)
    docs = docs.copy() if docs is not None else None
    bags = getattr(batch, "bags", None)
    bags = bags.copy() if bags is not None else None

    lists: List[np.ndarray] = []
    for s in range(n):
        sl = slice(s * per, (s + 1) * per)
        parts = [tokens[sl].ravel(), negs[sl].ravel()]
        if uniq is not None:
            parts.append(uniq[sl].ravel())
        if docs is not None:
            parts.append(docs[sl].ravel())
        if bags is not None:
            parts.append(bags[sl].ravel())
        flat = np.concatenate(parts)
        # `>= hot` also drops the -1 pads docs/bags carry
        lists.append(first_seen_unique(flat[flat >= hot]).astype(np.int64))

    width = max(max((len(li) for li in lists), default=0), 1)
    width = -(-width // _REQUEST_PAD) * _REQUEST_PAD
    cold_ids = np.full((n, width), -1, dtype=np.int32)

    # one shared remap table, patched per shard with only that shard's
    # request list (O(distinct) per shard, not O(V)): hot ids map to
    # themselves; unseen cold ids map to 0 (a hot row) — they never occur
    # in the shard's arrays by construction, so any hit means a planner
    # bug, which the bit-parity tests would surface immediately
    remap = np.arange(placement.vocab_size, dtype=np.int32)
    remap[hot:] = 0
    for s, li in enumerate(lists):
        sl = slice(s * per, (s + 1) * per)
        cold_ids[s, :len(li)] = li
        remap[li] = hot + np.arange(len(li), dtype=np.int32)
        tokens[sl] = remap[tokens[sl]]
        negs[sl] = remap[negs[sl]]
        if uniq is not None:
            uniq[sl] = remap[uniq[sl]]
        if docs is not None:
            docs[sl] = _remap_masked(remap, docs[sl])
        if bags is not None:
            bags[sl] = _remap_masked(remap, bags[sl])
        remap[li] = 0   # restore for the next shard

    bucket_ids, bucket_pos = _plan_buckets(lists, placement, width)

    kw = {}
    if plan is not None:
        kw = dict(plan_uniq=uniq, plan_scatter=plan.scatter,
                  plan_ucount=plan.ucount, plan_strict=plan.strict)
    return VocabExchange(placement=placement, tokens=tokens, negs=negs,
                         lengths=batch.lengths, cold_ids=cold_ids,
                         n_distinct=[len(li) for li in lists],
                         bucket_ids=bucket_ids, bucket_pos=bucket_pos,
                         docs=docs, bags=bags, **kw)


def _remap_masked(remap: np.ndarray, arr: np.ndarray) -> np.ndarray:
    """Apply the working-table remap, preserving -1 sentinels (missing doc
    row / bag padding) instead of reading ``remap[-1]``."""
    return np.where(arr >= 0, remap[np.maximum(arr, 0)], -1)


def _plan_buckets(lists: List[np.ndarray], placement: VocabPlacement,
                  width: int) -> Tuple[np.ndarray, np.ndarray]:
    """Re-sort per-shard request lists into per-owner capacity buckets.

    Returns ``(bucket_ids, bucket_pos)``, both ``(n, n, C)``:
    ``bucket_ids[s, o]`` is the sub-list of shard s's requests owned by
    shard o (-1 padded), ``bucket_pos[s, o]`` each id's first-seen position
    in shard s's request list (pad slots hold ``width`` — one past the
    gathered block — so a ``mode="drop"`` scatter discards them). C is the
    max per-owner count over all ``(s, o)`` pairs, rounded up to
    ``_BUCKET_PAD`` so shapes stay static across a run's typical batches.
    """
    n, hot = placement.n_shards, placement.hot
    owners = [((li - hot) % n).astype(np.int64) for li in lists]
    cap = max((int(np.max(np.bincount(ow, minlength=n), initial=0))
               for ow in owners if ow.size), default=0)
    cap = max(-(-max(cap, 1) // _BUCKET_PAD) * _BUCKET_PAD, _BUCKET_PAD)
    bucket_ids = np.full((n, n, cap), -1, dtype=np.int32)
    bucket_pos = np.full((n, n, cap), width, dtype=np.int32)
    for s, (li, ow) in enumerate(zip(lists, owners)):
        for o in range(n):
            pos = np.nonzero(ow == o)[0]
            bucket_ids[s, o, :len(pos)] = li[pos]
            bucket_pos[s, o, :len(pos)] = pos
    return bucket_ids, bucket_pos
