"""Logical-axis sharding rules with divisibility-adaptive resolution.

Models annotate activations with logical axis names via ``constrain`` and
stay mesh-agnostic; a surrounding ``axis_rules(mesh)`` context resolves the
names to mesh axes. Resolution silently drops a mesh axis when the dimension
is not divisible by it (e.g. starcoder2's 2 KV heads on a 16-way ``model``
axis → replicated), so every assigned architecture shards on the production
mesh without per-arch special cases.

Parameter shardings (`param_shardings`) implement TP over ``model`` ×
FSDP/ZeRO over ``data``; optimizer state follows parameters.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# logical name -> candidate mesh axes (first-fit by divisibility)
DEFAULT_RULES: Dict[str, Tuple[MeshAxes, ...]] = {
    "batch":     (("pod", "data"), ("data",)),
    "seq":       (None,),
    "kv_seq":    (("pod", "data"), ("data",)),   # long-context KV sharding
    "kv_seq_model": ("model",),  # KV seq over model when kv heads can't
    "expert_groups": (("pod", "data"), ("data",)),  # local MoE dispatch
    "embed":     (None,),
    "heads":     ("model",),
    "kv_heads":  ("model",),
    "head_dim":  ("model",),
    "ff":        ("model",),
    "experts":   ("model",),
    "capacity":  (("pod", "data"), ("data",)),
    "vocab":     ("model",),
    # W2V cold-tail embedding rows (hot head replicated): shard over data —
    # the vocab-scaling axis of distributed.vocab_placement (DESIGN.md §8).
    # "data" only: the W2V step's collectives run over that one axis name.
    "cold_vocab": (("data",),),
    "fsdp":      (("pod", "data"), ("data",)),
    "ssm_heads": ("model",),
    "inner":     ("model",),                     # mamba d_inner
    "stack":     (None,),                        # scanned-layer leading dim
    # ZeRO sharding of the replicated embed table's optimizer state
    "vocab_opt": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "d_opt":     ("model",),
}

# Right-sized parallelism for models whose per-chip compute is too small to
# amortize 16-way TP stream collectives: the whole mesh becomes one
# ZeRO-data-parallel domain (EXPERIMENTS.md §Perf-hillclimb).
PURE_DP_OVERRIDES: Dict[str, Tuple[MeshAxes, ...]] = {
    "batch":        (("pod", "data", "model"),),
    "fsdp":         (("pod", "data", "model"),),
    "expert_groups": (("pod", "data", "model"),),
    "vocab_opt":    (("pod", "data", "model"),),
    "heads": (None,), "kv_heads": (None,), "head_dim": (None,),
    "ff": (None,), "experts": (None,), "vocab": (None,),
    "inner": (None,), "ssm_heads": (None,), "capacity": (None,),
    "d_opt": (None,), "kv_seq_model": (None,),
}


class Rules:
    def __init__(self, mesh: Mesh, overrides: Optional[Dict] = None):
        self.mesh = mesh
        self.table = dict(DEFAULT_RULES)
        if overrides:
            self.table.update(overrides)

    def _axes_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape.get(a, 1)
        return size

    def _present(self, axes: MeshAxes) -> MeshAxes:
        """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on the
        single-pod mesh)."""
        if axes is None:
            return None
        if isinstance(axes, str):
            return axes if axes in self.mesh.shape else None
        kept = tuple(a for a in axes if a in self.mesh.shape)
        return kept or None

    # axes where GSPMD uneven sharding (implicit padding) beats replication:
    # e.g. 24 attention heads on a 16-way model axis -> 2 (padded from 1.5)
    # heads per device instead of 24 replicated.
    UNEVEN_OK = frozenset({"heads", "group", "ssm_heads"})

    def resolve(self, logical: Optional[str], dim: int,
                allow_uneven: bool = True) -> MeshAxes:
        """Pick the first candidate whose size divides `dim` (or pads, for
        UNEVEN_OK axes — intermediates only: jit argument shardings must
        divide exactly, so param_shardings resolves with
        allow_uneven=False)."""
        if logical is None:
            return None
        uneven = allow_uneven and logical in self.UNEVEN_OK
        for cand in self.table.get(logical, (None,)):
            cand = self._present(cand)
            sz = self._axes_size(cand)
            if sz > 1 and (dim % sz == 0 or (uneven and dim > 1)):
                return cand
            if cand is None or sz == 1:
                continue
        return None

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int], allow_uneven: bool = True) -> P:
        used = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self.resolve(name, dim, allow_uneven)
            # a mesh axis may appear at most once in a spec
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else axes
                if any(a in used for a in flat):
                    axes = None
                else:
                    used.update(flat)
            parts.append(axes)
        return P(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int],
                 allow_uneven: bool = True) -> NamedSharding:
        return NamedSharding(self.mesh,
                             self.spec(logical_axes, shape, allow_uneven))


def vocab_shard_sharding(mesh: Mesh, cold_pad: int) -> NamedSharding:
    """NamedSharding for a W2V cold-tail embedding table ``(cold_pad, d)``:
    rows over the ``data`` axis per the ``cold_vocab`` rule. The trainer
    places the cold tables with this so the ``shard_map`` step's
    ``P("data")`` in_spec finds them already distributed."""
    axes = Rules(mesh).resolve("cold_vocab", cold_pad, allow_uneven=False)
    return NamedSharding(mesh, P(axes))


_ACTIVE: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "sharding_rules", default=None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, overrides: Optional[Dict] = None):
    tok = _ACTIVE.set(Rules(mesh, overrides))
    try:
        yield _ACTIVE.get()
    finally:
        _ACTIVE.reset(tok)


@contextlib.contextmanager
def activate_rules(rules: Rules):
    """Activate a pre-built Rules instance (e.g. serve-mode overrides)."""
    tok = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(tok)


def current_rules() -> Optional[Rules]:
    return _ACTIVE.get()


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if rules are active; no-op otherwise."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(logical_axes, x.shape))


# --------------------------------------------------------------------------
# parameter shardings (TP over 'model', FSDP over 'data')
# --------------------------------------------------------------------------
_PARAM_AXES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # name regex -> logical axes of the *unstacked* parameter
    # input embed table: REPLICATED as a parameter (local gather; see
    # models.lm.embed_lookup) but ZeRO-sharded as optimizer state
    (r"embed$",            (None, None)),
    (r"unembed$",          (None, "vocab")),
    (r"wq$",               ("fsdp", "heads", "head_dim")),
    (r"w[kv]$",            ("fsdp", "kv_heads", None)),
    (r"wo$",               ("heads", "head_dim", "fsdp")),
    (r"[qk]_norm$",        (None,)),
    (r"w_router$",         (None, None)),
    (r"we_(gate|up)$",     ("experts", "fsdp", "ff")),      # MoE experts
    (r"we_down$",          ("experts", "ff", "fsdp")),
    (r"w_(gate|up)$",      ("fsdp", "ff")),                 # dense SwiGLU
    (r"w_down$",           ("ff", "fsdp")),
    (r"w_[zx]$",           ("fsdp", "inner")),              # mamba projections
    (r"w_(bc|dt)$",        ("fsdp", None)),
    (r"w_out$",            ("inner", "fsdp")),              # mamba out_proj
    (r"conv_",             None),                           # tiny -> replicate
    (r"(A_log|D|dt_bias)$", None),
    (r"norm$",             None),
)


def _leaf_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    name = path.split("/")[-1]
    for pat, axes in _PARAM_AXES:
        if re.search(pat, name):
            if axes is None:
                return tuple([None] * ndim)
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim - 1:       # scanned (layer-stacked) leaf
                return ("stack",) + tuple(axes)
            return tuple([None] * ndim)
    return tuple([None] * ndim)


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_shardings(params, rules: Rules, role: str = "param"):
    """Pytree of NamedShardings matching `params` (arrays or
    ShapeDtypeStructs). role="opt" applies ZeRO overrides (e.g. the
    replicated embed table's m/v shard over the whole mesh)."""

    def leaf_sharding(path, leaf):
        name = _path_str(path)
        if role == "opt" and name.split("/")[-1] == "embed":
            logical = ("vocab_opt", "d_opt")
        else:
            logical = _leaf_logical_axes(name, leaf.ndim)
        # jit arguments must shard evenly (XLA pads intermediates only)
        return rules.sharding(logical, leaf.shape, allow_uneven=False)

    return jax.tree_util.tree_map_with_path(leaf_sharding, params)
