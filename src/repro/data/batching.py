"""The CPU "batching" component of FULL-W2V (paper §4.1, Table 1).

Responsibilities (all host-side, exactly as the paper assigns them):
  * encode + subsample sentences,
  * optionally ignore sentence delimiters (stream packing — paper §4.1:
    "<0.5% additional word pairings", better utilization),
  * pack sentences into fixed-shape (S, L) int32 batches + lengths,
  * pre-sample per-window negatives (S, L, N) with the distinctness
    invariant the kernel relies on.

The device step consumes dense arrays only — no indirection on-device.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.data.corpus import Corpus
from repro.data.negatives import NegativeSampler
from repro.data.vocab import Vocab


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray    # (S, L) int32
    negs: np.ndarray      # (S, L, N) int32
    lengths: np.ndarray   # (S,) int32
    n_words: int          # real (unpadded) words in the batch


@dataclasses.dataclass
class BatchingStats:
    words: int = 0
    seconds: float = 0.0

    @property
    def words_per_sec(self) -> float:
        return self.words / self.seconds if self.seconds else float("inf")


class BatchingPipeline:
    def __init__(self, corpus: Corpus, cfg: W2VConfig,
                 vocab: Optional[Vocab] = None):
        self.cfg = cfg
        self.corpus = corpus
        self.vocab = vocab or Vocab.build(corpus.sentences,
                                          min_count=cfg.min_count)
        self.sampler = NegativeSampler(self.vocab.unigram_weights(),
                                       seed=cfg.seed + 1)
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = BatchingStats()

    # -- sentence stream ----------------------------------------------------
    def _encoded_stream(self) -> Iterator[List[int]]:
        cfg = self.cfg
        if cfg.ignore_delimiters:
            # stream-packing mode: concatenate the corpus and re-split into
            # max-length pseudo-sentences (paper §4.1)
            buf: List[int] = []
            for s in self.corpus.sentences:
                enc = self.vocab.subsample(self.vocab.encode(s),
                                           cfg.subsample_t, self.rng)
                buf.extend(enc)
                while len(buf) >= cfg.max_sentence_len:
                    yield buf[:cfg.max_sentence_len]
                    buf = buf[cfg.max_sentence_len:]
            if len(buf) > 1:
                yield buf
        else:
            for s in self.corpus.sentences:
                enc = self.vocab.subsample(self.vocab.encode(s),
                                           cfg.subsample_t, self.rng)
                for i in range(0, len(enc), cfg.max_sentence_len):
                    chunk = enc[i:i + cfg.max_sentence_len]
                    if len(chunk) > 1:
                        yield chunk

    # -- batches ------------------------------------------------------------
    def batches(self, pad_len: Optional[int] = None) -> Iterator[Batch]:
        """One epoch of (S, L) batches. `pad_len` fixes L (jit shape reuse);
        default = cfg.max_sentence_len."""
        cfg = self.cfg
        L = pad_len or cfg.max_sentence_len
        S = cfg.sentences_per_batch
        toks = np.zeros((S, L), np.int32)
        lens = np.zeros((S,), np.int32)
        row = 0
        for sent in self._encoded_stream():
            t0 = time.perf_counter()
            n = min(len(sent), L)
            toks[row, :n] = sent[:n]
            lens[row] = n
            row += 1
            self.stats.seconds += time.perf_counter() - t0
            if row == S:
                yield self._finalize(toks, lens)
                toks = np.zeros((S, L), np.int32)
                lens = np.zeros((S,), np.int32)
                row = 0
        if row:
            yield self._finalize(toks[:row], lens[:row], pad_rows=S - row)

    def _finalize(self, toks: np.ndarray, lens: np.ndarray,
                  pad_rows: int = 0) -> Batch:
        t0 = time.perf_counter()
        negs = self.sampler.sample_batch(toks, self.cfg.negatives)
        if pad_rows:
            toks = np.pad(toks, ((0, pad_rows), (0, 0)))
            negs = np.pad(negs, ((0, pad_rows), (0, 0), (0, 0)))
            lens = np.pad(lens, (0, pad_rows))
        n_words = int(lens.sum())
        self.stats.seconds += time.perf_counter() - t0
        self.stats.words += n_words
        return Batch(tokens=toks, negs=negs, lengths=lens, n_words=n_words)

    @property
    def epoch_words(self) -> int:
        """Approximate trainable words per epoch (post min-count)."""
        return self.vocab.total
