"""The CPU "batching" component of FULL-W2V (paper §4.1, Table 1).

Responsibilities (all host-side, exactly as the paper assigns them):
  * encode + subsample sentences,
  * optionally ignore sentence delimiters (stream packing — paper §4.1:
    "<0.5% additional word pairings", better utilization),
  * pack sentences into fixed-shape (S, L) int32 batches + lengths,
  * pre-sample per-window negatives (S, L, N) with the distinctness
    invariant the kernel relies on,
  * conflict-aware window tiling (DESIGN.md §4): group T consecutive
    windows per kernel step, deduplicate the tile's T·(N+1) output rows
    into a compacted unique-row list + scatter map, and flag tiles whose
    output rows collide across windows (``strict``) so the kernel can
    fall back to the exact sequential path for them.

The device step consumes dense arrays only — no indirection on-device.

Randomness is *keyed*, not streamed (DESIGN.md §4.1): subsampling draws
depend only on ``(seed, epoch, sentence_block)`` and negative draws only on
``(seed, epoch, batch_index)``. Every batch is therefore a pure function of
``(corpus, cfg, epoch, batch_index)`` — which is what lets the async
pipeline (``data/prefetch.py``) farm finalization out to any number of
workers in any order and still emit a stream bit-identical to this
synchronous pipeline, and what makes mid-epoch resume exact
(``skip_batches`` skips work, not randomness).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.w2v import W2VConfig
from repro.data.corpus import Corpus
from repro.data.negatives import NegativeSampler
from repro.data.vocab import Vocab

# Sentences per subsampling-rng key (and per async encode work unit). Fixed:
# changing it changes the subsample stream (it is part of the data layout,
# like sentences_per_batch), so it is a module constant, not a config knob.
ENCODE_BLOCK = 256

# Domain-separation tags so the subsample and negative streams never collide
# even where their (epoch, index) coordinates do.
_SUBSAMPLE_TAG = 0x5B5A
_NEGATIVES_TAG = 0x4E45


def subsample_rng(seed: int, epoch: int, block_index: int
                  ) -> np.random.Generator:
    """The keyed subsampling stream for one ENCODE_BLOCK of sentences."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, _SUBSAMPLE_TAG, epoch, block_index]))


def negatives_rng(seed: int, epoch: int, batch_index: int
                  ) -> np.random.Generator:
    """The keyed negative-sampling stream for one batch."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, _NEGATIVES_TAG, epoch, batch_index]))


def first_seen_unique(flat: np.ndarray) -> np.ndarray:
    """Distinct values of ``flat`` in first-occurrence order.

    The same dedup rule :func:`plan_tiles` applies to a window tile's
    output slots, exposed for callers that dedup at other granularities —
    the vocab-sharding exchange planner applies it per mesh shard
    (``distributed.vocab_placement.plan_exchange``) so each shard's working
    table lays rows out in the order its sentences first touch them.
    """
    _, idx = np.unique(flat, return_index=True)
    return flat[np.sort(idx)]


def encode_block(vocab: Vocab, sentences: Sequence[Sequence],
                 subsample_t: float, rng: np.random.Generator
                 ) -> List[np.ndarray]:
    """Encode + subsample one block of raw sentences (vectorized LUT +
    masked-draw fast path — bit-identical to the scalar ``encode`` /
    ``subsample`` pair). Pure given the rng."""
    return [vocab.subsample_ids(vocab.encode_ids(s), subsample_t, rng)
            for s in sentences]


@dataclasses.dataclass
class TilePlan:
    """Host-side schedule for the tiled kernel (`_kernel_tiled`).

    A *tile* is ``tile`` consecutive window positions of one sentence. Its
    output rows are the T targets + T·N negatives, laid out slot-major:
    slot ``w*(N+1) + 0`` is window ``t0+w``'s target, slots ``w*(N+1)+1..N``
    its negatives. The plan compacts those slots to unique vocab rows so the
    kernel fetches/writes each row exactly once per tile (write-once).

    Collision policy (DESIGN.md §4): a *negative* repeated across windows is
    fused — it is exactly pWord2Vec's shared-negative relaxation lifted from
    one window to T, and dedup keeps the fetch/write-once invariant. A
    repeat that touches a *target* slot (target/target, or target appearing
    as another window's negative) conflicts on the positive label and is
    where the pre-tile-value relaxation distorts most, so those tiles are
    marked ``strict`` and replayed sequentially by the kernel.
    """
    tile: int             # T — windows per tile
    uniq: np.ndarray      # (S, nt, T*(N+1)) int32 — unique rows, first-seen
                          # order; columns >= ucount are 0 (masked)
    scatter: np.ndarray   # (S, nt, T*(N+1)) int32 — slot -> column in uniq;
                          # slots of windows beyond the sentence map to 0
    ucount: np.ndarray    # (S, nt) int32 — number of valid uniq columns
    strict: np.ndarray    # (S, nt) int32 — 1 iff a repeated row involves a
                          # *target* slot (sequential fallback; see below)

    @property
    def n_tiles(self) -> int:
        return self.uniq.shape[1]


def plan_tiles(tokens: np.ndarray, negs: np.ndarray, lengths: np.ndarray,
               tile: int) -> TilePlan:
    """Build the conflict-aware tile schedule for a batch.

    Fully vectorised (no per-tile Python loop): first-seen-order dedup is
    computed with a stable argsort per tile row. First-seen order matters —
    it makes the T=1 plan lay rows out exactly as the sequential kernel
    ([target, neg_1..neg_N]), which is what makes `_kernel_tiled` at T=1
    bit-identical to `_kernel`.
    """
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    S, L = tokens.shape
    N = negs.shape[-1]
    m = N + 1
    nt = -(-L // tile)                    # ceil(L / tile)
    Lp = nt * tile
    M = tile * m                          # output slots per tile

    tk = np.pad(tokens, ((0, 0), (0, Lp - L))).astype(np.int64)
    ng = np.pad(negs, ((0, 0), (0, Lp - L), (0, 0))).astype(np.int64)
    slots = np.concatenate([tk[..., None], ng], axis=-1)   # (S, Lp, m)
    rows = slots.reshape(S * nt, M)
    valid = (np.arange(Lp)[None, :] < lengths[:, None])    # (S, Lp) windows
    valid = np.repeat(valid[..., None], m, axis=-1).reshape(S * nt, M)

    # Invalid slots (windows past the sentence end — always a suffix of the
    # tile) get one shared sentinel that first-occurs after every valid slot,
    # so its dedup group lands past the valid columns.
    sentinel = np.int64(1) << 40
    rows = np.where(valid, rows, sentinel)

    B = S * nt
    ar = np.arange(M)[None, :]
    order = np.argsort(rows, axis=1, kind="stable")        # (B, M)
    srt = np.take_along_axis(rows, order, axis=1)
    new = np.ones((B, M), dtype=bool)
    new[:, 1:] = srt[:, 1:] != srt[:, :-1]
    # index (sorted order) of each value's group start, forward-filled
    gstart = np.maximum.accumulate(np.where(new, ar, 0), axis=1)
    # original slot of each value's first occurrence (stable sort => min slot)
    first_sorted = np.take_along_axis(order, gstart, axis=1)
    fs = np.empty((B, M), dtype=np.int64)
    np.put_along_axis(fs, order, first_sorted, axis=1)     # per-slot first
    is_first = fs == ar
    ranks = np.cumsum(is_first, axis=1) - 1                # first-seen rank
    cols = np.take_along_axis(ranks, fs, axis=1)           # slot -> column

    ucount = (is_first & valid).sum(axis=1)
    # per-slot multiplicity of the slot's dedup group (valid slots only)
    occ = np.zeros((B, M), dtype=np.int32)
    np.add.at(occ, (np.arange(B)[:, None], cols), valid.astype(np.int32))
    slot_mult = np.take_along_axis(occ, cols, axis=1)
    is_target = (np.arange(M) % m == 0)[None, :]
    strict = ((slot_mult > 1) & is_target & valid).any(axis=1)
    strict = strict.astype(np.int32)

    uniq = np.zeros((B, M), dtype=np.int64)
    np.put_along_axis(uniq, cols, rows, axis=1)
    uniq[ar >= ucount[:, None]] = 0                        # mask padding
    scatter = np.where(valid, cols, 0)

    return TilePlan(
        tile=tile,
        uniq=uniq.reshape(S, nt, M).astype(np.int32),
        scatter=scatter.reshape(S, nt, M).astype(np.int32),
        ucount=ucount.reshape(S, nt).astype(np.int32),
        strict=strict.reshape(S, nt),
    )


def plan_costs(plan: TilePlan, lengths: np.ndarray, n_neg: int,
               gemm_windows: int = 0) -> dict:
    """Exact per-batch DMA / GEMM counts the tiled kernel will issue, by
    replaying the plan against the kernel's runtime guards (the kernel's
    control flow is deterministic given the plan). Used by
    ``benchmarks/bench_tile_sweep.py``; the T=1 numbers reproduce the
    sequential kernel's costs.

    Counts: one "dma" = one single-row ``make_async_copy``; one "gemm" = one
    ``dot_general`` issued to the MXU (3 per window update: corr, d_ctx,
    d_out; fused tiles issue 3 per GEMM group of ``gemm_windows``).
    """
    from repro.configs.w2v import resolve_gemm_windows
    m = n_neg + 1
    T = plan.tile
    G = resolve_gemm_windows(T, gemm_windows)
    S, nt = plan.ucount.shape
    windows = int(lengths.sum())
    ring_dmas = 2 * windows            # each position: 1 load + 1 store
    out_dmas = 0
    gemms = 0
    for s in range(S):
        ln = int(lengths[s])
        for i in range(-(-ln // T)):
            n_valid = min(T, ln - i * T)
            if plan.strict[s, i]:
                out_dmas += 2 * m * n_valid    # per-window fetch + write
                gemms += 3 * n_valid
            else:
                out_dmas += 2 * int(plan.ucount[s, i])
                gemms += 3 * (-(-n_valid // G))   # one triple per group
    return {
        "windows": windows,
        "dma_total": ring_dmas + out_dmas,
        "dma_ring": ring_dmas,
        "dma_out_rows": out_dmas,
        "gemms": gemms,
        "dma_per_window": (ring_dmas + out_dmas) / max(windows, 1),
        "gemms_per_window": gemms / max(windows, 1),
    }


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray    # (S, L) int32
    negs: np.ndarray      # (S, L, N) int32
    lengths: np.ndarray   # (S,) int32
    n_words: int          # real (unpadded) words in the batch
    plan: Optional[TilePlan] = None   # set when cfg.tile_windows > 1
    # frontend extras (DESIGN.md §12): per-sentence static context row
    # (doc2vec — already mapped to table-extra space ``vocab.size + doc``,
    # -1 for none) and per-position bag members (fastText subwords —
    # (S, L, B) table rows, -1 padded; positions past the sentence length
    # are all -1 so exchange request lists stay exact)
    docs: Optional[np.ndarray] = None
    bags: Optional[np.ndarray] = None
    # vocab-sharding exchange plan (distributed.vocab_placement
    # .VocabExchange), attached when the pipeline carries a placement —
    # so request dedup + capacity bucketing run in the finalize workers,
    # off the training loop's critical path
    exchange: Optional[object] = None
    # position of this batch in the keyed-randomness counter space: the
    # same (epoch, index) pair that keyed its subsample/negative draws.
    # Consumers that need more per-batch keyed randomness (the trainer's
    # stochastic storage-rounding key) derive it from these counters so it
    # replays identically at any worker count
    epoch: int = 0
    index: int = 0

    def step_inputs(self, lr) -> "StepInputs":
        """Lift this host batch into the engine API's device-side struct
        (``repro.kernels.registry.StepInputs``), tile plan included."""
        # local import: keeps this module jax-free until a step is built
        from repro.kernels.registry import StepInputs
        return StepInputs.from_batch(self, lr)


@dataclasses.dataclass
class BatchingStats:
    """Host batching throughput counters.

    ``seconds`` measures *steady-state batching only*: the clock starts when
    the first batch begins to be produced, so pipeline construction (vocab
    build, alias-table build) and time spent suspended waiting on the
    consumer never count. ``words_per_sec`` is therefore the Table-1 number
    — what the host stage can sustain — not an end-to-end figure diluted by
    one-time setup.
    """
    words: int = 0
    seconds: float = 0.0

    @property
    def words_per_sec(self) -> float:
        return self.words / self.seconds if self.seconds else float("inf")


@dataclasses.dataclass
class PackedBatch:
    """Stage-2 output: an assembled (rows, L) token block, pre-negatives.
    ``index`` is the batch's position in the epoch stream — the key of its
    negative-sampling rng, and the unit the async pipeline shards over."""
    index: int
    tokens: np.ndarray    # (rows, L) int32, rows <= S for the final batch
    lengths: np.ndarray   # (rows,) int32
    pad_rows: int         # rows to pad back up to S at finalize time
    docs: Optional[np.ndarray] = None   # (rows,) int32 table rows, -1 none


def finalize_packed(packed: PackedBatch, cfg: W2VConfig,
                    sampler: NegativeSampler, epoch: int,
                    placement=None, bag_table=None) -> Batch:
    """Stage 3: negatives + tile plan (+ vocab-sharding exchange plan when
    ``placement`` is given; + bag materialization when the pipeline carries
    a ``bag_table``) for one packed batch. Pure given ``(packed, cfg,
    sampler table, epoch, placement, bag_table)`` — the keyed rng means any
    worker, in any order, produces the identical Batch, and
    ``plan_exchange`` is rng-free, so the attached exchange inherits the
    same determinism."""
    toks, lens = packed.tokens, packed.lengths
    docs = packed.docs
    rng = negatives_rng(cfg.seed, epoch, packed.index)
    if cfg.tile_windows > 1:
        # tile-shared negatives (Ji et al. HogBatch): one N-set per T
        # consecutive windows — the dedup win of the tiled kernel
        negs = sampler.sample_batch_tiled(
            toks, cfg.negatives, cfg.tile_windows, lens, rng=rng)
    else:
        negs = sampler.sample_batch(toks, cfg.negatives, rng=rng)
    if packed.pad_rows:
        toks = np.pad(toks, ((0, packed.pad_rows), (0, 0)))
        negs = np.pad(negs, ((0, packed.pad_rows), (0, 0), (0, 0)))
        lens = np.pad(lens, (0, packed.pad_rows))
        if docs is not None:
            docs = np.pad(docs, (0, packed.pad_rows), constant_values=-1)
    n_words = int(lens.sum())
    plan = None
    if cfg.tile_windows > 1:
        plan = plan_tiles(toks, negs, lens, cfg.tile_windows)
    bags = None
    if bag_table is not None:
        # (S, L, B) member rows per token position; positions past the
        # sentence length masked to -1 so sharded request lists only carry
        # rows the kernel actually touches
        pos = np.arange(toks.shape[1])[None, :] < lens[:, None]
        bags = np.where(pos[..., None], bag_table[toks], -1).astype(np.int32)
    batch = Batch(tokens=toks, negs=negs, lengths=lens, n_words=n_words,
                  plan=plan, docs=docs, bags=bags,
                  epoch=epoch, index=packed.index)
    if placement is not None:
        # local import: keeps this module free of distributed/ unless a
        # sharded session actually hands its placement to the pipeline
        from repro.distributed.vocab_placement import plan_exchange
        batch.exchange = plan_exchange(batch, placement)
    return batch


class BatchingPipeline:
    def __init__(self, corpus: Corpus, cfg: W2VConfig,
                 vocab: Optional[Vocab] = None):
        self.cfg = cfg
        self.corpus = corpus
        self.vocab = vocab or Vocab.build(corpus.sentences,
                                          min_count=cfg.min_count)
        self.sampler = NegativeSampler(self.vocab.unigram_weights(),
                                       seed=cfg.seed + 1)
        self.stats = BatchingStats()
        # vocab-sharding placement: a sharded TrainSession deposits its
        # VocabPlacement here so finalize plans the row exchange per batch
        # (None => batches carry no exchange and the trainer plans inline)
        self.placement = None
        # frontend state (DESIGN.md §12), attached by a workload's
        # prepare(): table rows past the vocabulary (doc rows / n-gram
        # buckets, appended at [vocab.size, table_rows)), the per-word
        # bag-membership table ((V, B) int32, -1 padded; member 0 is the
        # word row itself), and the kernel features batches will carry
        self.extra_rows = 0
        self.bag_table: Optional[np.ndarray] = None
        self.frontend_features: tuple = ()
        # epoch key when batches() is called without one: each call is the
        # next epoch, mirroring TrainSession's per-epoch iteration
        self._auto_epoch = 0

    @property
    def table_rows(self) -> int:
        """Embedding-table rows the trainer must allocate: vocabulary plus
        frontend extras (doc rows, n-gram buckets)."""
        return self.vocab.size + self.extra_rows

    def table_counts(self) -> np.ndarray:
        """Occurrence counts over the full table. Frontend extras count
        zero, so ``VocabPlacement.plan`` always stripes them into the
        sharded cold tail and the negative sampler (built from the vocab's
        unigram weights alone) can never draw them."""
        if not self.extra_rows:
            return self.vocab.counts
        return np.concatenate(
            [self.vocab.counts, np.zeros(self.extra_rows, np.int64)])

    def _resolve_epoch(self, epoch: Optional[int]) -> int:
        if epoch is None:
            epoch = self._auto_epoch
        self._auto_epoch = epoch + 1
        return epoch

    # -- stage 1: encode + subsample ----------------------------------------
    def _encoded_blocks(self, epoch: int) -> Iterator[List[List[int]]]:
        """ENCODE_BLOCK-sized blocks of encoded+subsampled sentences, each
        drawn from its own keyed rng."""
        sents = self.corpus.sentences
        for start in range(0, len(sents), ENCODE_BLOCK):
            rng = subsample_rng(self.cfg.seed, epoch, start // ENCODE_BLOCK)
            yield encode_block(self.vocab, sents[start:start + ENCODE_BLOCK],
                               self.cfg.subsample_t, rng)

    def _encoded_stream(self, epoch: int
                        ) -> Iterator[Tuple[List[int], int]]:
        """Yield ``(encoded_chunk, doc)`` pairs; ``doc`` is the raw
        per-sentence document id, -1 when the corpus carries none."""
        cfg = self.cfg
        doc_ids = getattr(self.corpus, "doc_ids", None)
        n_seen = 0
        if cfg.ignore_delimiters:
            # stream-packing mode: concatenate the corpus and re-split into
            # max-length pseudo-sentences (paper §4.1)
            buf: List[int] = []
            cur = -1
            for block in self._encoded_blocks(epoch):
                for enc in block:
                    doc = doc_ids[n_seen] if doc_ids is not None else -1
                    n_seen += 1
                    if doc_ids is not None and doc != cur and buf:
                        # document boundary: flush the packing buffer. A
                        # pseudo-sentence spliced across documents would
                        # let windows near the join borrow context from
                        # the neighbouring document — exactly what the
                        # injected static doc row makes visible (and
                        # wrong: one row, two documents)
                        if len(buf) > 1:
                            yield buf, cur
                        buf = []
                    cur = doc
                    buf.extend(enc)
                    while len(buf) >= cfg.max_sentence_len:
                        yield buf[:cfg.max_sentence_len], cur
                        buf = buf[cfg.max_sentence_len:]
            if len(buf) > 1:
                yield buf, cur
        else:
            for block in self._encoded_blocks(epoch):
                for enc in block:
                    doc = doc_ids[n_seen] if doc_ids is not None else -1
                    n_seen += 1
                    for i in range(0, len(enc), cfg.max_sentence_len):
                        chunk = enc[i:i + cfg.max_sentence_len]
                        if len(chunk) > 1:
                            yield chunk, doc

    # -- stage 2: pack into fixed-shape blocks ------------------------------
    def _packed(self, pad_len: Optional[int], epoch: int,
                timed: bool = True) -> Iterator[PackedBatch]:
        """Assemble the epoch's encoded stream into indexed (S, L) token
        blocks. Deterministic given (corpus, cfg, epoch) — both pipelines
        share it, so their batch indexing agrees by construction."""
        cfg = self.cfg
        L = pad_len or cfg.max_sentence_len
        S = cfg.sentences_per_batch
        with_docs = getattr(self.corpus, "doc_ids", None) is not None
        V = self.vocab.size
        toks = np.zeros((S, L), np.int32)
        lens = np.zeros((S,), np.int32)
        docs = np.full((S,), -1, np.int32)
        row = 0
        index = 0
        stream = self._encoded_stream(epoch)
        while True:
            t0 = time.perf_counter()
            item = next(stream, None)
            if timed:   # encode+subsample time counts as batching work
                self.stats.seconds += time.perf_counter() - t0
            if item is None:
                break
            sent, doc = item
            t0 = time.perf_counter()
            chunks = [sent[i:i + L] for i in range(0, len(sent), L)]
            for chunk in chunks:
                if len(chunk) < 2:
                    continue
                toks[row, :len(chunk)] = chunk
                lens[row] = len(chunk)
                # doc rows live in table-extra space, past the vocabulary
                docs[row] = V + doc if doc >= 0 else -1
                row += 1
                if row == S:
                    if timed:
                        self.stats.seconds += time.perf_counter() - t0
                    yield PackedBatch(index, toks, lens, 0,
                                      docs=docs if with_docs else None)
                    index += 1
                    toks = np.zeros((S, L), np.int32)
                    lens = np.zeros((S,), np.int32)
                    docs = np.full((S,), -1, np.int32)
                    row = 0
                    t0 = time.perf_counter()
            if timed:
                self.stats.seconds += time.perf_counter() - t0
        if row:
            yield PackedBatch(index, toks[:row], lens[:row], S - row,
                              docs=docs[:row] if with_docs else None)

    # -- batches ------------------------------------------------------------
    def batches(self, pad_len: Optional[int] = None,
                epoch: Optional[int] = None,
                skip_batches: int = 0) -> Iterator[Batch]:
        """One epoch of (S, L) batches. `pad_len` fixes L (jit shape reuse);
        default = cfg.max_sentence_len. Sentences longer than L are split
        into L-sized rows (dropping trailing single-word chunks, which have
        no window) — no tokens are silently truncated.

        `epoch` keys this epoch's randomness (default: one more than the
        previous call). `skip_batches` fast-forwards past the epoch's first
        k batches without finalizing them — because randomness is keyed by
        batch index, the remaining stream is bit-identical to the suffix of
        a full epoch (exact mid-epoch resume)."""
        epoch = self._resolve_epoch(epoch)
        for packed in self._packed(pad_len, epoch):
            if packed.index < skip_batches:
                continue
            t0 = time.perf_counter()
            batch = finalize_packed(packed, self.cfg, self.sampler, epoch,
                                    self.placement, self.bag_table)
            self.stats.seconds += time.perf_counter() - t0
            self.stats.words += batch.n_words
            yield batch

    @property
    def epoch_words(self) -> int:
        """Approximate trainable words per epoch (post min-count)."""
        return self.vocab.total
