from repro.data.batching import Batch, BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus, synthetic_zipf_corpus
from repro.data.negatives import NegativeSampler
from repro.data.vocab import Vocab

__all__ = [
    "Batch", "BatchingPipeline", "NegativeSampler", "Vocab",
    "synthetic_cluster_corpus", "synthetic_zipf_corpus",
]
