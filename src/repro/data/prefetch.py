"""Async host batching: multi-worker finalization + bounded prefetch.

FULL-W2V assigns encoding, subsampling, negative pre-sampling, and (here)
tile planning to the CPU *so the host can run ahead of the device* (paper
§4.1, Table 1). :class:`AsyncBatchingPipeline` is that overlap: a producer
thread walks the deterministic encode→pack stages while a pool of workers
finalizes batches (negative sampling + ``plan_tiles`` — the ~90% of host
time, all GIL-releasing numpy) into a bounded in-order queue the training
loop drains.

Determinism does not come from scheduling — it comes from the keyed
randomness in ``data/batching.py``: every batch is a pure function of
``(corpus, cfg, epoch, batch_index)``, so any worker count, any executor
interleaving, and the synchronous pipeline all emit bit-identical streams
(``tests/test_prefetch.py`` pins this). Ordering is restored by consuming
futures in submission order.

Stages (DESIGN.md §4.1):

    producer thread:  encode+subsample blocks -> pack (S, L) -> submit
    worker pool:      finalize_packed (negatives, tile plan)   [xN]
    consumer:         in-order bounded queue -> training step

Backpressure: at most ``depth`` finalized-or-in-flight batches exist ahead
of the consumer (a BoundedSemaphore the consumer releases per yield), so a
stalled device never piles up unbounded host memory.

``mode="thread"`` shares the pipeline state directly and scales because
finalization is numpy (GIL released); ``mode="process"`` ships the vocab
and alias table to worker processes once at pool start, for workloads
where python-heavy encode/subsample dominates.

Self-healing (DESIGN.md §9): a *killed* process worker breaks the whole
pool (``BrokenProcessPool``) — instead of killing the epoch, the pipeline
rebuilds the pool and recomputes every batch the dead pool still owed.
Finalization is a pure function of ``(packed, cfg, epoch)``, so the
recomputed batches are bit-identical and the emitted stream never changes
(``PrefetchStats.heals`` counts pool rebuilds). A dead *producer* thread
surfaces as a :class:`PipelineFault` on the consumer within a bounded
poll interval — a recoverable step failure, never a hang. Task
*exceptions* (the finalize function itself raising) still propagate:
they are deterministic, so retrying them would fail identically.
"""
from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
from concurrent.futures import (BrokenExecutor, CancelledError, Executor,
                                Future)
from typing import Iterator, List, Optional

log = logging.getLogger("repro.prefetch")

from repro.configs.w2v import W2VConfig
from repro.data.batching import (Batch, BatchingPipeline, PackedBatch,
                                 finalize_packed)
from repro.data.corpus import Corpus
from repro.data.negatives import NegativeSampler
from repro.data.vocab import Vocab

# ---------------------------------------------------------------------------
# Process-mode worker state: shipped once via the pool initializer so each
# finalize task carries only its PackedBatch, not the alias table (nor the
# vocab-sharding placement).
# ---------------------------------------------------------------------------
_WORKER_CFG: Optional[W2VConfig] = None
_WORKER_SAMPLER: Optional[NegativeSampler] = None
_WORKER_PLACEMENT = None
_WORKER_BAGS = None


def _proc_init(cfg: W2VConfig, sampler: NegativeSampler,
               placement=None, bag_table=None) -> None:
    global _WORKER_CFG, _WORKER_SAMPLER, _WORKER_PLACEMENT, _WORKER_BAGS
    _WORKER_CFG = cfg
    _WORKER_SAMPLER = sampler
    _WORKER_PLACEMENT = placement
    _WORKER_BAGS = bag_table


def _proc_ready() -> bool:
    """No-op task: submitting it forces a worker process to spawn and run
    its initializer (unpickling the cfg + alias table)."""
    return True


def _proc_finalize(packed: PackedBatch, epoch: int) -> Batch:
    return finalize_packed(packed, _WORKER_CFG, _WORKER_SAMPLER, epoch,
                           _WORKER_PLACEMENT, _WORKER_BAGS)


@dataclasses.dataclass
class _EndOfEpoch:
    """Queue sentinel: the producer finished (or failed with ``error``)."""
    error: Optional[BaseException] = None


class PipelineFault(RuntimeError):
    """The host pipeline died in a way a supervisor can recover from by
    re-opening the stream (producer thread gone without its sentinel, or a
    worker pool that could not be healed)."""


@dataclasses.dataclass
class _Pending:
    """One submitted finalize: the input kept alongside its future so a
    broken pool can recompute the batch bit-identically."""
    packed: PackedBatch
    epoch: int
    future: Future
    gen: int        # executor generation the future was submitted to


@dataclasses.dataclass
class PrefetchStats:
    """Observability for the overlap benchmarks: queue depth over time and
    the backpressure high-water mark, plus the self-healing counter."""
    max_in_flight: int = 0          # most batches ever past the semaphore
    heals: int = 0                  # worker pools rebuilt after breakage
    depth_samples: List[int] = dataclasses.field(default_factory=list)

    @property
    def mean_depth(self) -> float:
        d = self.depth_samples
        return sum(d) / len(d) if d else 0.0


class AsyncBatchingPipeline(BatchingPipeline):
    """Drop-in :class:`BatchingPipeline` whose ``batches()`` produces ahead
    of the consumer. Bit-identical stream, overlapped wall clock.

    Parameters default to the config's ``prefetch_*`` knobs; ``workers=0``
    is coerced to 1 (an async pipeline with no workers is the sync one —
    construct :class:`BatchingPipeline` for that).
    """

    def __init__(self, corpus: Corpus, cfg: W2VConfig,
                 vocab: Optional[Vocab] = None,
                 workers: Optional[int] = None,
                 depth: Optional[int] = None,
                 mode: Optional[str] = None):
        super().__init__(corpus, cfg, vocab)
        self.workers = max(1, cfg.prefetch_workers if workers is None
                           else workers)
        self.depth = max(1, cfg.prefetch_depth if depth is None else depth)
        self.mode = mode or cfg.prefetch_mode
        if self.mode not in ("thread", "process"):
            raise ValueError(
                f"prefetch_mode must be 'thread' or 'process', "
                f"got {self.mode!r}")
        self.prefetch = PrefetchStats()
        self.ready_depth = 0   # finalized batches waiting, as of last yield
        # exposed for tests: the machinery of the most recent batches() call
        self._producer: Optional[threading.Thread] = None
        self._executor: Optional[Executor] = None
        # pool-heal state: the lock serializes executor swap + submit, the
        # generation counter tells a failed future whether its pool was
        # already replaced (resubmit) or still needs healing (rebuild)
        self._ex_lock = threading.Lock()
        self._ex_gen = 0

    # -- executor ------------------------------------------------------------
    def _make_executor(self) -> Executor:
        if self.mode == "process":
            from concurrent.futures import ProcessPoolExecutor
            return ProcessPoolExecutor(
                max_workers=self.workers, initializer=_proc_init,
                initargs=(self.cfg, self.sampler, self.placement,
                          self.bag_table))
        from concurrent.futures import ThreadPoolExecutor
        return ThreadPoolExecutor(max_workers=self.workers,
                                  thread_name_prefix="w2v-finalize")

    def _warm(self, ex: Executor) -> None:
        """Spawn and initialize every process worker up front. Without
        this, workers spawn lazily at the first submits: each spawn forks,
        re-imports, and unpickles the cfg + alias table — one-time setup
        cost that lands inside the steady-state stats window and was
        billed to process-mode throughput (the BENCH_6 async_process
        regression). Thread pools have no per-worker state to warm."""
        if self.mode != "process":
            return
        from concurrent.futures import wait
        wait([ex.submit(_proc_ready) for _ in range(self.workers)])

    def _submit(self, ex: Executor, packed: PackedBatch,
                epoch: int) -> Future:
        if self.mode == "process":
            return ex.submit(_proc_finalize, packed, epoch)
        return ex.submit(finalize_packed, packed, self.cfg, self.sampler,
                         epoch, self.placement, self.bag_table)

    # -- pool healing --------------------------------------------------------
    def _heal_locked(self) -> None:
        """Replace a broken worker pool (caller holds ``_ex_lock``). The
        dead pool's pending finalizes are recomputed by whoever owns their
        ``_Pending`` — deterministic, so the stream stays bit-identical."""
        try:
            self._executor.shutdown(wait=False, cancel_futures=True)
        except Exception:  # noqa: BLE001 — a broken pool may refuse even this
            pass
        self._executor = self._make_executor()
        self._warm(self._executor)
        self._ex_gen += 1
        self.prefetch.heals += 1
        log.warning("worker pool died — respawned (heal #%d)",
                    self.prefetch.heals)

    def _submit_pending(self, packed: PackedBatch, epoch: int) -> _Pending:
        """Producer-side submit that survives a dead pool: heal and retry
        once (a fresh pool that breaks immediately is a real fault)."""
        with self._ex_lock:
            try:
                fut = self._submit(self._executor, packed, epoch)
            except BrokenExecutor:
                self._heal_locked()
                fut = self._submit(self._executor, packed, epoch)
            return _Pending(packed, epoch, fut, self._ex_gen)

    def _result_healing(self, pend: _Pending) -> Batch:
        """Consumer-side result that survives a dead pool: on breakage,
        heal (unless another thread already did) and recompute this batch
        on the fresh pool. Task exceptions propagate — deterministic
        inputs would just fail again."""
        retries = 0
        while True:
            try:
                return pend.future.result()
            except (BrokenExecutor, CancelledError) as e:
                retries += 1
                if retries > self.workers + 2:
                    raise PipelineFault(
                        f"worker pool kept dying ({retries} heals for one "
                        f"batch)") from e
                with self._ex_lock:
                    if pend.gen == self._ex_gen:
                        self._heal_locked()
                    pend.future = self._submit(self._executor, pend.packed,
                                               pend.epoch)
                    pend.gen = self._ex_gen

    def worker_pids(self) -> List[int]:
        """Live process-pool worker pids (empty for thread mode) — the
        chaos harness's kill target (``tools/chaos.py``)."""
        procs = getattr(self._executor, "_processes", None)
        return list(procs.keys()) if procs else []

    # -- the async stream ----------------------------------------------------
    def batches(self, pad_len: Optional[int] = None,
                epoch: Optional[int] = None,
                skip_batches: int = 0) -> Iterator[Batch]:
        """Same contract (and same bits) as the synchronous ``batches()``;
        production runs ahead on the worker pool, bounded by ``depth``."""
        epoch = self._resolve_epoch(epoch)
        self._executor = self._make_executor()
        self._warm(self._executor)  # worker spawn/init is setup, not steady
        slots = threading.BoundedSemaphore(self.depth)
        out: "queue.Queue[object]" = queue.Queue()
        stop = threading.Event()
        in_flight = [0]              # guarded by lock, for the high-water mark
        lock = threading.Lock()

        def produce() -> None:
            try:
                # stats are wall-based here (production is concurrent);
                # timed=False keeps the sync per-stage deltas out of them
                for packed in self._packed(pad_len, epoch, timed=False):
                    if packed.index < skip_batches:
                        continue
                    while not slots.acquire(timeout=0.05):   # backpressure
                        if stop.is_set():
                            return
                    if stop.is_set():
                        return
                    with lock:
                        in_flight[0] += 1
                        self.prefetch.max_in_flight = max(
                            self.prefetch.max_in_flight, in_flight[0])
                    out.put(self._submit_pending(packed, epoch))
                out.put(_EndOfEpoch())
            except BaseException as e:  # noqa: BLE001 — relayed to consumer
                out.put(_EndOfEpoch(error=e))

        producer = threading.Thread(target=produce, name="w2v-producer",
                                    daemon=True)
        self._producer = producer
        wall0 = time.perf_counter()
        stats_base = self.stats.seconds
        idle = 0.0   # suspended-in-consumer time while the pipeline was idle
        producer.start()
        try:
            while True:
                try:
                    item = out.get(timeout=1.0)
                except queue.Empty:
                    # bounded poll: a producer that died *between* queue
                    # puts (OOM-killed, uncaught BaseException path lost)
                    # must surface as a recoverable fault, not a hang
                    if not producer.is_alive():
                        raise PipelineFault(
                            "producer thread died without delivering "
                            "end-of-epoch")
                    continue
                if isinstance(item, _EndOfEpoch):
                    if item.error is not None:
                        raise item.error
                    return
                batch = self._result_healing(item)
                with lock:
                    in_flight[0] -= 1
                    pending = in_flight[0]
                self.ready_depth = self._ready_depth(out)
                slots.release()
                self.prefetch.depth_samples.append(self.ready_depth)
                self.stats.words += batch.n_words
                # steady-state clock (BatchingStats contract): wall time
                # since the first production activity, minus stretches the
                # generator sat suspended in the consumer while the whole
                # pipeline was drained-and-waiting (backpressured) — those
                # are consumer time, not batching time
                self.stats.seconds = (stats_base
                                      + (time.perf_counter() - wall0) - idle)
                pipeline_idle = self.ready_depth >= pending
                t_yield = time.perf_counter()
                yield batch
                if pipeline_idle:
                    idle += time.perf_counter() - t_yield
        finally:
            stop.set()
            # drain queued work so shutdown never deadlocks on
            # cancelled-but-queued tasks
            while True:
                try:
                    item = out.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, _Pending):
                    item.future.cancel()
            producer.join(timeout=10.0)
            # self._executor, not a local: healing may have replaced it
            self._executor.shutdown(wait=True, cancel_futures=True)

    @staticmethod
    def _ready_depth(out: "queue.Queue[object]") -> int:
        """Finalized batches sitting ready ahead of the consumer."""
        with out.mutex:
            return sum(1 for p in out.queue
                       if isinstance(p, _Pending) and p.future.done())


def make_pipeline(corpus: Corpus, cfg: W2VConfig,
                  vocab: Optional[Vocab] = None) -> BatchingPipeline:
    """The config-selected pipeline: async when ``cfg.prefetch_workers > 0``,
    synchronous otherwise. The single construction point the CLI, examples,
    and benchmarks share."""
    if cfg.prefetch_workers > 0:
        return AsyncBatchingPipeline(corpus, cfg, vocab)
    return BatchingPipeline(corpus, cfg, vocab)
