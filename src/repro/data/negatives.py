"""Unigram^0.75 negative pre-sampler (the CPU side of the paper's §4.1
coordination: "batching is precomputation, random sampling, and assembly of
data into a format friendly for GPU").

Uses the alias method for O(1) draws. Guarantees the FULL-W2V kernel's
per-window invariant: the N negatives of a window are distinct from each
other and from the target word (classic word2vec also rejects
negative == target).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class AliasTable:
    """Walker alias method over an unnormalized weight vector."""

    def __init__(self, weights: np.ndarray):
        w = np.asarray(weights, dtype=np.float64)
        assert w.ndim == 1 and (w >= 0).all() and w.sum() > 0
        n = len(w)
        p = w * n / w.sum()
        self.n = n
        self.prob = np.ones(n)
        self.alias = np.arange(n)
        small = [i for i in range(n) if p[i] < 1.0]
        large = [i for i in range(n) if p[i] >= 1.0]
        p = p.copy()
        while small and large:
            s, l = small.pop(), large.pop()
            self.prob[s] = p[s]
            self.alias[s] = l
            p[l] = p[l] + p[s] - 1.0
            (small if p[l] < 1.0 else large).append(l)
        for rest in (small, large):
            for i in rest:
                self.prob[i] = 1.0

    def sample(self, shape, rng: np.random.Generator) -> np.ndarray:
        idx = rng.integers(0, self.n, size=shape)
        accept = rng.random(size=shape) < self.prob[idx]
        return np.where(accept, idx, self.alias[idx])


class NegativeSampler:
    def __init__(self, weights: np.ndarray, seed: int = 0):
        self.table = AliasTable(weights)
        self.rng = np.random.default_rng(seed)
        self.vocab = len(weights)

    def sample_batch(self, targets: np.ndarray, n_neg: int,
                     rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """Negatives for every window of a (S, L) target batch -> (S, L, N).

        Per-window distinctness (incl. vs target) via bounded rejection
        resampling; falls back to a deterministic fill in the (vanishingly
        unlikely) case rejection does not converge.

        `rng` overrides the sampler's own stream — the keyed-randomness hook
        the batching pipelines use so every batch's draws depend only on
        ``(seed, epoch, batch_index)``, never on who sampled before
        (DESIGN.md §4.1: worker-count-invariant async batching).
        """
        if rng is None:
            rng = self.rng
        S, L = targets.shape
        negs = self.table.sample((S, L, n_neg), rng).astype(np.int32)
        for _ in range(16):
            bad = self._conflicts(targets, negs)
            if not bad.any():
                return negs
            resampled = self.table.sample(negs.shape, rng).astype(np.int32)
            negs = np.where(bad, resampled, negs)
        # deterministic fallback: walk ids upward until conflict-free
        bad = self._conflicts(targets, negs)
        while bad.any():
            negs = np.where(bad, (negs + 1) % self.vocab, negs)
            bad = self._conflicts(targets, negs)
        return negs

    def sample_batch_tiled(self, targets: np.ndarray, n_neg: int,
                           tile: int,
                           lengths: Optional[np.ndarray] = None,
                           rng: Optional[np.random.Generator] = None
                           ) -> np.ndarray:
        """One shared N-set per *tile* of ``tile`` consecutive windows,
        broadcast to every window of the tile -> (S, L, N).

        This is Ji et al.'s (1604.04661) shared-negative batching lifted to
        the tile granularity of `_kernel_tiled` (DESIGN.md §4): the tile's
        output block shrinks from T·(N+1) rows to ~T+N, which is what makes
        the tiled kernel's batched fetch ≥2× smaller per window. Each set is
        distinct internally and from *all* T targets of its tile, so the
        per-window invariant (negatives ≠ target, pairwise distinct) still
        holds for every window and the tile scheduler never sees a
        target-as-negative collision.

        `rng` overrides the sampler's stream (see :meth:`sample_batch`).
        """
        if rng is None:
            rng = self.rng
        S, L = targets.shape
        nt = -(-L // tile)
        Lp = nt * tile
        tg = np.full((S, Lp), -1, dtype=np.int64)
        tg[:, :L] = targets
        if lengths is not None:
            tg[np.arange(Lp)[None, :] >= np.asarray(lengths)[:, None]] = -1
        tg = tg.reshape(S, nt, tile)
        negs = self.table.sample((S, nt, n_neg), rng).astype(np.int32)
        for _ in range(16):
            bad = self._tile_conflicts(tg, negs)
            if not bad.any():
                break
            resampled = self.table.sample(negs.shape,
                                          rng).astype(np.int32)
            negs = np.where(bad, resampled, negs)
        bad = self._tile_conflicts(tg, negs)
        # deterministic fallback: each pass advances every conflicted slot,
        # so `vocab` passes visit every id — if conflicts persist past that,
        # some tile has fewer than n_neg non-target ids (infeasible config)
        for _ in range(self.vocab):
            if not bad.any():
                break
            negs = np.where(bad, (negs + 1) % self.vocab, negs)
            bad = self._tile_conflicts(tg, negs)
        if bad.any():
            raise ValueError(
                f"cannot draw {n_neg} negatives distinct from all targets "
                f"of a {tile}-window tile with vocab={self.vocab}; reduce "
                f"tile_windows or negatives, or grow the vocabulary")
        out = np.repeat(negs[:, :, None, :], tile, axis=2).reshape(S, Lp,
                                                                   n_neg)
        return np.ascontiguousarray(out[:, :L])

    @staticmethod
    def _tile_conflicts(tile_targets: np.ndarray,
                        negs: np.ndarray) -> np.ndarray:
        """(S, nt, N) bool — negative equals any target of its tile or an
        earlier negative of the same set."""
        bad = (negs[..., None] == tile_targets[:, :, None, :]).any(-1)
        n = negs.shape[-1]
        for j in range(1, n):
            dup = (negs[:, :, j:j + 1] == negs[:, :, :j]).any(-1)
            bad[:, :, j] |= dup
        return bad

    @staticmethod
    def _conflicts(targets: np.ndarray, negs: np.ndarray) -> np.ndarray:
        """(S, L, N) bool — negative equals target or an earlier negative in
        the same window."""
        bad = negs == targets[:, :, None]
        n = negs.shape[-1]
        for j in range(1, n):
            dup = (negs[:, :, j:j + 1] == negs[:, :, :j]).any(-1)
            bad[:, :, j] |= dup
        return bad
