"""Corpus sources.

Text8 / One-Billion-Words are not redistributable in this offline container
(DESIGN.md §7); we generate synthetic corpora that match their statistical
profile for throughput work (Zipf-distributed unigrams) and add *planted
cluster structure* for embedding-quality measurement (the Table-7 analogue:
words in the same latent topic co-occur, so a correct SGNS implementation
must embed them nearby).

Real text ingestion (`load_text`) is included for deployments with data.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Corpus:
    """A corpus is a list of sentences; each sentence a list of raw tokens
    (strings or ints — the vocab maps them)."""
    sentences: List[List[int]]
    vocab_size: int
    # ground-truth cluster id per word (synthetic corpora only)
    clusters: Optional[np.ndarray] = None
    # per-sentence document id (doc2vec frontend, DESIGN.md §12): when set,
    # len(doc_ids) == len(sentences) and the batching pipeline threads each
    # sentence's doc through to ``Batch.docs`` as an always-in-window static
    # context row. Stream packing (ignore_delimiters) flushes at document
    # boundaries so no pseudo-sentence spans two documents.
    doc_ids: Optional[List[int]] = None

    def __post_init__(self):
        if (self.doc_ids is not None
                and len(self.doc_ids) != len(self.sentences)):
            raise ValueError(
                f"doc_ids has {len(self.doc_ids)} entries for "
                f"{len(self.sentences)} sentences")

    @property
    def n_words(self) -> int:
        return sum(len(s) for s in self.sentences)


def synthetic_zipf_corpus(
    vocab_size: int = 10_000,
    n_sentences: int = 2_000,
    mean_len: int = 20,
    zipf_a: float = 1.2,
    seed: int = 0,
) -> Corpus:
    """Zipf-distributed token stream, shaped like Text8's frequency profile."""
    rng = np.random.default_rng(seed)
    lens = np.maximum(2, rng.poisson(mean_len, n_sentences))
    ranks = rng.zipf(zipf_a, size=int(lens.sum()))
    toks = np.minimum(ranks - 1, vocab_size - 1).astype(np.int64)
    out, i = [], 0
    for ln in lens:
        out.append(toks[i:i + ln].tolist())
        i += ln
    return Corpus(out, vocab_size)


def synthetic_cluster_corpus(
    n_clusters: int = 16,
    words_per_cluster: int = 32,
    n_sentences: int = 4_000,
    mean_len: int = 16,
    purity: float = 0.9,
    seed: int = 0,
) -> Corpus:
    """Planted-topic corpus: each sentence draws ~`purity` of its words from
    one latent cluster, the rest uniformly. SGNS must embed same-cluster
    words closer than cross-cluster words — `core.quality` measures it."""
    rng = np.random.default_rng(seed)
    v = n_clusters * words_per_cluster
    clusters = np.repeat(np.arange(n_clusters), words_per_cluster)
    sentences = []
    for _ in range(n_sentences):
        ln = max(4, rng.poisson(mean_len))
        c = rng.integers(n_clusters)
        in_cluster = rng.random(ln) < purity
        words = np.where(
            in_cluster,
            c * words_per_cluster + rng.integers(0, words_per_cluster, ln),
            rng.integers(0, v, ln),
        )
        sentences.append(words.astype(np.int64).tolist())
    return Corpus(sentences, v, clusters=clusters)


def load_text(path: str, max_sentence_len: int = 1000) -> Iterator[List[str]]:
    """Stream whitespace-tokenized sentences from a text file (one sentence
    per line; lines longer than `max_sentence_len` are split, matching the
    paper's 1,000-word cap, Table 3)."""
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        for line in f:
            words = line.split()
            for i in range(0, len(words), max_sentence_len):
                chunk = words[i:i + max_sentence_len]
                if chunk:
                    yield chunk
