"""Vocabulary construction, min-count filtering, and frequency subsampling.

Follows Mikolov et al.: words with fewer than `min_count` occurrences are
dropped (paper Table 3: min 5); frequent words are randomly discarded with
probability 1 - sqrt(t/f(w)) (t = subsample threshold, default 1e-4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Iterable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Vocab:
    ids: Dict[Hashable, int]          # raw token -> dense id
    counts: np.ndarray                # (V,) occurrence counts
    total: int                        # total kept-word occurrences
    # lazy caches (not part of the value): int-token lookup table (with a
    # memoized not-LUT-able verdict) and per-threshold keep probabilities —
    # the vectorized encode/subsample fast path the host pipeline's hot
    # loop runs on
    _lut: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    _lut_checked: bool = dataclasses.field(
        default=False, repr=False, compare=False)
    _keep_cache: Dict[float, np.ndarray] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def size(self) -> int:
        return len(self.counts)

    @classmethod
    def build(cls, sentences: Iterable[Sequence[Hashable]],
              min_count: int = 5) -> "Vocab":
        raw: Dict[Hashable, int] = {}
        for s in sentences:
            for w in s:
                raw[w] = raw.get(w, 0) + 1
        kept = sorted((w for w, c in raw.items() if c >= min_count),
                      key=lambda w: (-raw[w], str(w)))
        ids = {w: i for i, w in enumerate(kept)}
        counts = np.array([raw[w] for w in kept], dtype=np.int64)
        return cls(ids=ids, counts=counts, total=int(counts.sum()))

    # -- encode: LUT fast path for int-token corpora -------------------------
    def _int_lut(self) -> Optional[np.ndarray]:
        """raw int token -> dense id (or -1), when every raw token is a
        smallish non-negative int (synthetic corpora, pre-tokenized text).
        None when the vocabulary is not LUT-able (string tokens) — the
        verdict is memoized either way, so the check is paid once, not per
        sentence."""
        if not self._lut_checked:
            self._lut_checked = True
            keys = list(self.ids)
            ok = (bool(keys)
                  and all(isinstance(k, (int, np.integer)) for k in keys)
                  and min(keys) >= 0 and max(keys) < 1 << 24)
            if ok:
                lut = np.full(int(max(keys)) + 1, -1, dtype=np.int32)
                for k, i in self.ids.items():
                    lut[int(k)] = i
                self._lut = lut
        return self._lut

    def encode(self, sentence: Sequence[Hashable]) -> List[int]:
        return [self.ids[w] for w in sentence if w in self.ids]

    def encode_ids(self, sentence: Sequence[Hashable]) -> np.ndarray:
        """Vectorized :meth:`encode` -> int32 array. Identical output (OOV
        dropped — including negative or non-int tokens — order kept); the
        batching hot loop runs on this."""
        lut = self._int_lut()
        if lut is not None:
            try:
                raw = np.asarray(sentence)
            except ValueError:   # ragged input
                raw = None
            # ints only: float/str/object sentences take the scalar path,
            # which drops them as OOV rather than silently truncating
            if raw is not None and raw.dtype.kind in "iu" and raw.ndim == 1:
                raw = raw.astype(np.int64)
                if raw.size == 0:
                    return raw.astype(np.int32)
                in_range = (raw >= 0) & (raw < len(lut))
                enc = lut[np.where(in_range, raw, 0)]
                enc = np.where(in_range, enc, -1)
                return enc[enc >= 0].astype(np.int32)
        return np.asarray(self.encode(sentence), dtype=np.int32)

    def keep_probs(self, subsample_t: float) -> np.ndarray:
        """P(keep) per word id under Mikolov subsampling (cached per t)."""
        p = self._keep_cache.get(subsample_t)
        if p is None:
            f = self.counts / max(self.total, 1)
            with np.errstate(divide="ignore", invalid="ignore"):
                p = np.sqrt(subsample_t / f)
            p = np.clip(p, 0.0, 1.0)
            self._keep_cache[subsample_t] = p
        return p

    def subsample(self, sentence: Sequence[int], subsample_t: float,
                  rng: np.random.Generator) -> List[int]:
        if subsample_t <= 0:
            return list(sentence)
        keep = self.keep_probs(subsample_t)
        return [w for w in sentence if rng.random() < keep[w]]

    def subsample_ids(self, ids: np.ndarray, subsample_t: float,
                      rng: np.random.Generator) -> np.ndarray:
        """Vectorized :meth:`subsample`, bit-identical stream: ``rng.random
        (n)`` consumes the generator exactly like n scalar draws, so the
        kept set matches the scalar path draw for draw."""
        if subsample_t <= 0 or ids.size == 0:
            return ids
        keep = self.keep_probs(subsample_t)
        return ids[rng.random(ids.shape[0]) < keep[ids]]

    def unigram_weights(self, power: float = 0.75) -> np.ndarray:
        """The negative-sampling distribution weights f(w)^0.75."""
        return self.counts.astype(np.float64) ** power
