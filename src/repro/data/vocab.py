"""Vocabulary construction, min-count filtering, and frequency subsampling.

Follows Mikolov et al.: words with fewer than `min_count` occurrences are
dropped (paper Table 3: min 5); frequent words are randomly discarded with
probability 1 - sqrt(t/f(w)) (t = subsample threshold, default 1e-4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Iterable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Vocab:
    ids: Dict[Hashable, int]          # raw token -> dense id
    counts: np.ndarray                # (V,) occurrence counts
    total: int                        # total kept-word occurrences

    @property
    def size(self) -> int:
        return len(self.counts)

    @classmethod
    def build(cls, sentences: Iterable[Sequence[Hashable]],
              min_count: int = 5) -> "Vocab":
        raw: Dict[Hashable, int] = {}
        for s in sentences:
            for w in s:
                raw[w] = raw.get(w, 0) + 1
        kept = sorted((w for w, c in raw.items() if c >= min_count),
                      key=lambda w: (-raw[w], str(w)))
        ids = {w: i for i, w in enumerate(kept)}
        counts = np.array([raw[w] for w in kept], dtype=np.int64)
        return cls(ids=ids, counts=counts, total=int(counts.sum()))

    def encode(self, sentence: Sequence[Hashable]) -> List[int]:
        return [self.ids[w] for w in sentence if w in self.ids]

    def keep_probs(self, subsample_t: float) -> np.ndarray:
        """P(keep) per word id under Mikolov subsampling."""
        f = self.counts / max(self.total, 1)
        with np.errstate(divide="ignore", invalid="ignore"):
            p = np.sqrt(subsample_t / f)
        return np.clip(p, 0.0, 1.0)

    def subsample(self, sentence: Sequence[int], subsample_t: float,
                  rng: np.random.Generator) -> List[int]:
        if subsample_t <= 0:
            return list(sentence)
        keep = self.keep_probs(subsample_t)
        return [w for w in sentence if rng.random() < keep[w]]

    def unigram_weights(self, power: float = 0.75) -> np.ndarray:
        """The negative-sampling distribution weights f(w)^0.75."""
        return self.counts.astype(np.float64) ** power
