"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization; tests and benches see the real (single) device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has — for tests/examples. Factors the
    local device count into (data, model)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
