"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step per chip:

  compute    = HLO_FLOPs / peak_FLOPs            (cost_analysis, per device)
  memory     = HLO_bytes / HBM_bw                (cost_analysis bytes accessed)
  collective = Σ collective_bytes / ICI_link_bw  (parsed from partitioned HLO)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(single-link conservative estimate; see EXPERIMENTS.md §Roofline caveats).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (one link assumed)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# result of `op(...)`: e.g.  %ag = bf16[2,4096]{1,0} all-gather(%x), ...
# (tuple results e.g. all-to-all can list several shapes — captured greedily)
_COLLECTIVE_RE = re.compile(
    r"=\s*\(?((?:[a-z0-9]+\[[0-9,]*\][^ )]*(?:,\s*)?)+)\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))    # [num_groups, group_size]
    return 2


def _wire_factor(op: str, n: int) -> float:
    """Per-device wire bytes as a multiple of the RESULT size (ring algos)."""
    n = max(n, 2)
    if op == "all-gather":
        return (n - 1) / n          # result = gathered (full) tensor
    if op == "all-reduce":
        return 2 * (n - 1) / n      # reduce-scatter + all-gather of result
    if op == "reduce-scatter":
        return float(n - 1)         # result = 1/n of the operand
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0                      # collective-permute


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-op-kind wire bytes from a partitioned (per-device) HLO dump."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        b = _shape_bytes(shapes) * _wire_factor(op, _group_size(line))
        out[op] = out.get(op, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per-device FLOPs per step
    bytes_accessed: float      # per-device HBM bytes per step
    coll_bytes: float          # per-device collective wire bytes per step
    coll_breakdown: Dict[str, float]
    peak_memory_bytes: Optional[float] = None
    model_flops: Optional[float] = None     # 6·N·D (train) or 2·N·D (serve)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if the three units fully overlap."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_frac(self) -> float:
        """Fraction of the binding roof actually spent on model FLOPs
        (the score: model-useful compute / bound time)."""
        mf = self.model_flops if self.model_flops else self.flops
        t = self.t_bound
        return (mf / PEAK_FLOPS) / t if t else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def analyze(compiled, model_flops: Optional[float] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        peak = (getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0))
    return RooflineTerms(
        flops=flops,
        bytes_accessed=nbytes,
        coll_bytes=coll.get("total", 0.0),
        coll_breakdown=coll,
        peak_memory_bytes=peak,
        model_flops=model_flops,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS per device per step: 6·N_active·tokens (train),
    2·N_active·tokens (forward/serve), over all devices -> divided later."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
