"""Jittable train/serve steps + abstract input specs for every
(architecture × input shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no allocation);
``make_train_step``/``make_serve_step`` build the functions the dry-run
lowers and the launcher executes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, InputShape
from repro.distributed.sharding import Rules, axis_rules, param_shardings
from repro.models import lm
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: InputShape,
                param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.prefix_len:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), param_dtype)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.prefix_len:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), param_dtype)
    elif shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["cache"] = lm.init_cache(cfg, b, s, jnp.bfloat16)
        specs["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return specs


def batch_shardings(cfg: ArchConfig, shape: InputShape, rules: Rules):
    """Shardings matching input_specs."""
    specs = input_specs(cfg, shape)
    out: Dict[str, Any] = {}
    for name, sd in specs.items():
        if name == "cache":
            out[name] = lm.cache_shardings(cfg, rules, shape.global_batch,
                                           shape.seq_len)
        elif name == "cache_len":
            out[name] = NamedSharding(rules.mesh, P())
        elif name == "prefix_embeds":
            out[name] = rules.sharding(("batch", None, None), sd.shape)
        else:
            out[name] = rules.sharding(("batch", None), sd.shape)
    return out


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, opt: AdamWConfig,
                    microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        prefix = batch.get("prefix_embeds")

        def loss_fn(p, toks, labs, pref):
            return lm.lm_loss(cfg, p, toks, labs, pref)

        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                      labels, prefix)
        else:
            b = tokens.shape[0]
            assert b % microbatches == 0

            # python-unrolled accumulation (static trip count keeps
            # cost_analysis exact; XLA still schedules sequentially)
            mb_sz = b // microbatches
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            loss = jnp.float32(0.0)
            for i in range(microbatches):
                sl = lambda x: x[i * mb_sz:(i + 1) * mb_sz]
                l, g = jax.value_and_grad(loss_fn)(
                    params, sl(tokens), sl(labels),
                    None if prefix is None else sl(prefix))
                grads = jax.tree.map(jnp.add, grads, g)
                loss = loss + l
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        params, opt_state = adamw_update(opt, params, grads, opt_state)
        metrics = {"loss": loss, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        logits, cache, clen = lm.prefill(cfg, params, batch["tokens"],
                                         batch.get("prefix_embeds"))
        return {"logits": logits, "cache": cache, "cache_len": clen}

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode against a seq_len KV/state cache."""

    def serve_step(params, batch):
        logits, cache = lm.decode_step(cfg, params, batch["cache"],
                                       batch["cache_len"], batch["tokens"])
        return {"logits": logits, "cache": cache}

    return serve_step


# --------------------------------------------------------------------------
# jit assembly for a (cfg, shape, mesh) cell
# --------------------------------------------------------------------------
def build_cell(cfg: ArchConfig, shape_name: str, mesh: Mesh,
               opt: Optional[AdamWConfig] = None,
               param_dtype=jnp.bfloat16, microbatches: int = 1,
               zero_stage: int = 3, rule_overrides: Optional[Dict] = None):
    """Returns (jitted fn, example abstract args, rules) for lowering.

    Perf knobs (see EXPERIMENTS.md §Perf):
      zero_stage=3 — params FSDP-sharded over data (per-layer gathers);
      zero_stage=2 — params data-replicated, optimizer state still sharded
                     (one param all-gather per STEP instead of per layer —
                     wins when the TP-sharded copy fits HBM).
      rule_overrides — logical-axis table overrides (e.g. {"head_dim":
                     (None,)} to stop q/o reshard gathers on uneven-head
                     archs at the cost of replicated projections).
    """
    shape = SHAPES[shape_name]
    overrides = dict(rule_overrides or {})
    if zero_stage == 2:
        overrides["fsdp"] = (None,)
    rules = Rules(mesh, overrides or None)
    opt_rules = Rules(mesh, rule_overrides or None)  # opt state stays sharded
    if shape.kind != "train":
        # Serving: FSDP param-gathering per token is a latency disaster;
        # replicate params over `data` whenever the TP-sharded copy fits
        # HBM (<= ~12GB/chip), else keep ZeRO-3 sharding (arctic, jamba).
        model_par = mesh.shape.get("model", 1)
        if cfg.param_count() * 2 / model_par <= 12e9:
            rules = Rules(mesh, overrides={"fsdp": (None,)})
    p_abs = lm.abstract_params(cfg, param_dtype)
    p_shard = param_shardings(p_abs, rules)
    b_specs = input_specs(cfg, shape, param_dtype)
    b_shard = batch_shardings(cfg, shape, rules)

    def with_rules(fn):
        # `constrain` resolves logical axes at trace time — activate the
        # exact Rules used for param shardings whenever the step is traced.
        from repro.distributed.sharding import activate_rules

        @functools.wraps(fn)
        def wrapper(*a):
            with activate_rules(rules):
                return fn(*a)
        return wrapper

    if shape.kind == "train":
        opt = opt or AdamWConfig()
        step = with_rules(make_train_step(cfg, opt, microbatches))
        o_abs = jax.eval_shape(adamw_init, p_abs)
        from repro.train.optim import AdamWState
        opt_leaf_shard = param_shardings(p_abs, opt_rules, role="opt")
        o_shard = AdamWState(step=NamedSharding(mesh, P()),
                             m=opt_leaf_shard, v=opt_leaf_shard)
        jit = jax.jit(step,
                      in_shardings=(p_shard, o_shard, b_shard),
                      out_shardings=(p_shard, o_shard, None),
                      donate_argnums=(0, 1))
        args = (p_abs, o_abs, b_specs)
    elif shape.kind == "prefill":
        step = with_rules(make_prefill_step(cfg))
        jit = jax.jit(step, in_shardings=(p_shard, b_shard),
                      out_shardings=None)
        args = (p_abs, b_specs)
    else:
        step = with_rules(make_serve_step(cfg))
        out_shard = {"logits": rules.sharding(("batch", "vocab"),
                                              (shape.global_batch, cfg.vocab)),
                     "cache": lm.cache_shardings(cfg, rules,
                                                 shape.global_batch,
                                                 shape.seq_len)}
        jit = jax.jit(step, in_shardings=(p_shard, b_shard),
                      out_shardings=out_shard,
                      donate_argnums=(1,))
        args = (p_abs, b_specs)
    return jit, args, rules
