"""Embedding query server CLI (DESIGN.md §10).

Loads the newest checkpoint under ``--ckpt-dir`` into a sharded
:class:`~repro.serve.index.EmbeddingIndex`, stands up the batching
:class:`~repro.serve.server.EmbeddingServer` behind a
:class:`~repro.serve.snapshot.SnapshotWatcher`, answers a scripted query
load, and prints grep-able stats (the serve-smoke CI job's interface).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --ckpt-dir /tmp/ckpt \
      --queries 64 --check-oracle
  PYTHONPATH=src python -m repro.launch.serve --ckpt-dir /tmp/ckpt \
      --shards 2 --follow 10
"""
from __future__ import annotations

import argparse
import logging
import sys
import time


def main() -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True,
                    help="checkpoint directory to serve from (and follow)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve over N vocab shards (on CPU, N fake host "
                         "devices are synthesized); 0/1 = single device — "
                         "still the sharded code path on a 1-shard layout")
    ap.add_argument("--batch-size", type=int, default=32,
                    help="padded device batch the request coalescer cuts at")
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="max wait for co-riders before a batch is cut "
                         "short")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--queries", type=int, default=64,
                    help="scripted random queries to answer before exit")
    ap.add_argument("--mode", default="both",
                    choices=("nn", "analogy", "both"))
    ap.add_argument("--check-oracle", action="store_true",
                    help="recompute every response against the dense "
                         "single-host oracle for its snapshot step; "
                         "exit 1 on any mismatch")
    ap.add_argument("--follow", type=float, default=0.0,
                    help="after the scripted load, keep serving this many "
                         "seconds and report hot-swaps as they happen")
    ap.add_argument("--poll-s", type=float, default=0.25,
                    help="snapshot watcher poll cadence")
    ap.add_argument("--hot-frac", type=float, default=0.1,
                    help="serving hot-head fraction for replicated "
                         "(non-split) checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.shards > 1:
        # fake host devices must exist BEFORE jax initializes its backends
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.shards}")

    import jax
    import numpy as np

    from repro.serve import EmbeddingIndex, EmbeddingServer, SnapshotWatcher
    from repro.serve.query import dense_topk

    mesh = None
    if args.shards > 1:
        from repro.launch.mesh import make_host_mesh
        if jax.device_count() < args.shards:
            print(f"error: --shards {args.shards} needs {args.shards} "
                  f"devices, have {jax.device_count()}", file=sys.stderr)
            return 2
        mesh = make_host_mesh(model=1)

    def on_swap(old, new):
        print(f"swap: step {old.step if old else None} -> {new.step}",
              flush=True)

    watcher = SnapshotWatcher(args.ckpt_dir, mesh=mesh, poll_s=args.poll_s,
                              on_swap=on_swap)
    watcher.start()
    try:
        idx = watcher.wait_ready(timeout=60.0)
    except (TimeoutError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        watcher.stop()
        return 2
    print(f"serving: step={idx.step} vocab={idx.vocab_size} dim={idx.dim} "
          f"shards={idx.n_shards} hot={idx.placement.hot}")

    rng = np.random.default_rng(args.seed)
    server = EmbeddingServer(watcher, batch_size=args.batch_size,
                             deadline_ms=args.deadline_ms, k=args.k)
    kinds = {"nn": ("nn",), "analogy": ("analogy",),
             "both": ("nn", "analogy")}[args.mode]
    pending = []
    t0 = time.perf_counter()
    for i in range(args.queries):
        kind = kinds[i % len(kinds)]
        n = 1 + int(rng.integers(min(4, args.batch_size)))
        shape = (n,) if kind == "nn" else (n, 3)
        ids = rng.integers(idx.vocab_size, size=shape).astype(np.int32)
        pending.append((kind, ids, server.submit(kind, ids)))
    results = [(kind, ids, req.wait(60.0)) for kind, ids, req in pending]
    wall = time.perf_counter() - t0

    mismatches = 0
    if args.check_oracle:
        oracles = {}
        for kind, ids, res in results:
            step = res.snapshot_step
            if step not in oracles:
                oracles[step] = EmbeddingIndex.load(
                    args.ckpt_dir, step=step, mesh=mesh,
                    hot_frac=args.hot_frac).dense_embeddings()
            want_ids, want_sc = dense_topk(oracles[step], ids, k=args.k,
                                           mode=kind)
            if not (np.array_equal(res.ids, want_ids)
                    and np.allclose(res.scores, want_sc, atol=1e-5)):
                mismatches += 1
        print(f"oracle_parity={'ok' if mismatches == 0 else 'FAIL'} "
              f"checked={len(results)} mismatches={mismatches}")

    lat = np.asarray(server.latencies_us, np.float64)
    rows = sum(r.ids.shape[0] for _, _, r in results)
    print(f"serve_stats: queries={rows} batches={server.batches} "
          f"qps={rows / max(wall, 1e-9):,.0f} "
          f"p50_us={np.percentile(lat, 50):,.0f} "
          f"p99_us={np.percentile(lat, 99):,.0f}")

    if args.follow > 0:
        swaps0 = watcher.swaps
        print(f"following {args.ckpt_dir} for {args.follow:.0f}s "
              f"(poll every {args.poll_s}s)...")
        deadline = time.monotonic() + args.follow
        while time.monotonic() < deadline:
            time.sleep(min(0.2, args.poll_s))
        print(f"follow_done: swaps={watcher.swaps - swaps0} "
              f"now_serving_step={watcher.current().step}")

    server.close()
    watcher.stop()
    return 1 if mismatches else 0


if __name__ == "__main__":
    sys.exit(main())
