"""Render the dry-run JSONL results into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.configs.base import SHAPES, cells, get_arch


def load(path: str, tag: str = "baseline") -> Dict:
    out = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if tag and r.get("tag", "baseline") != tag:
                continue
            out[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return out


def fmt_ms(x) -> str:
    return f"{x * 1e3:8.2f}" if x is not None else "     n/a"


def render(results: Dict, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound |"
        " useful_flops | roofline_frac | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape in cells(include_skips=True):
        cfg = get_arch(arch)
        key = (arch, shape, mesh)
        if shape == "long_500k" and not cfg.supports_long_context():
            lines.append(f"| {arch} | {shape} | — | — | — | *skipped:"
                         f" full attention at 500k is quadratic* | — | — | — |")
            continue
        r = results.get(key)
        if r is None or r.get("status") != "ok":
            err = (r or {}).get("error", "missing")[:60]
            lines.append(f"| {arch} | {shape} | ERR | | | {err} | | | |")
            continue
        t = r["roofline"]
        star = "" if r.get("extrapolated", True) else " \\*"
        mem = r["memory_analysis"]
        hbm = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        uf = t.get("useful_flops_frac")
        lines.append(
            f"| {arch} | {shape}{star} |{fmt_ms(t['t_compute'])} |"
            f"{fmt_ms(t['t_memory'])} |{fmt_ms(t['t_collective'])} | "
            f"{t['bottleneck']} | "
            f"{(f'{uf:.3f}' if uf is not None else 'n/a')} | "
            f"{t['roofline_frac']:.3f} | {hbm / 1e9:.1f} |")
    lines.append("")
    lines.append("\\* compile-proof-only record (no loop-corrected cost "
                 "extrapolation): FLOP/collective terms count scan bodies "
                 "once and are unreliable — memory proof and compile "
                 "success stand; see §Dry-run methodology.")
    return "\n".join(lines)


def summarize(results: Dict) -> str:
    ok = [r for r in results.values() if r.get("status") == "ok"]
    err = [r for r in results.values() if r.get("status") != "ok"]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_frac"])[:5]
    coll = sorted(ok, key=lambda r: -r["roofline"]["t_collective"])[:5]
    out = [f"cells ok: {len(ok)}, errors: {len(err)}", "",
           "worst roofline_frac:"]
    for r in worst:
        out.append(f"  {r['arch']} × {r['shape']} × {r['mesh']}: "
                   f"{r['roofline']['roofline_frac']:.4f} "
                   f"({r['roofline']['bottleneck']})")
    out.append("most collective-bound:")
    for r in coll:
        out.append(f"  {r['arch']} × {r['shape']} × {r['mesh']}: "
                   f"t_coll {r['roofline']['t_collective'] * 1e3:.0f} ms")
    return "\n".join(out)


if __name__ == "__main__":
    res = load(sys.argv[1])
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(render(res, mesh))
    print()
    print(summarize(res))
