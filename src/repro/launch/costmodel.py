"""Analytic per-device HBM-traffic model for the roofline memory term.

WHY ANALYTIC: the dry-run compiles on the CPU backend, whose fusion pipeline
materializes elementwise chains that the TPU backend fuses away — HLO
"bytes accessed" from a CPU compile overestimates TPU HBM traffic by ~5-20×
(measured: 600GB of bare `convert` outputs in one 2-layer compile,
EXPERIMENTS.md §Roofline). FLOPs are fusion-invariant, so the compute term
keeps the extrapolated-HLO source; the memory term uses this model, which
follows standard TPU roofline accounting:

  * params: bf16 reads ×(fwd + remat-fwd + bwd), f32 grad RW, AdamW m/v RW,
    param write (train); single bf16 read (serve).
  * activations: per-layer residual/projection tensors RW, flash-attention
    KV block re-reads (n_q/2 passes over the causal prefix), MoE dispatch
    buffers, SSD chunk states — each counted at its sharded (per-device)
    size, forward counted twice under remat (recompute) plus backward.
  * embed/loss: one-hot contraction + vocab-sharded logits RW (f32 CE).
  * decode: full KV/state-cache read per token + params read (the classic
    decode bound), one cache-position write.

All formulas are per device per step, in bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, InputShape

BF16 = 2
F32 = 4


@dataclasses.dataclass
class Shards:
    batch: int      # devices sharding the batch/tokens
    model: int      # tensor-parallel degree
    fsdp: int       # parameter sharding over the data axis

    @classmethod
    def for_mesh(cls, multi_pod: bool) -> "Shards":
        return cls(batch=32 if multi_pod else 16, model=16,
                   fsdp=32 if multi_pod else 16)


def _attn_layer_bytes(cfg: ArchConfig, t_loc: int, s_ctx: int,
                      sh: Shards, training: bool) -> float:
    """Flash-attention layer activation traffic (per device)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    nh_loc = max(1, -(-cfg.n_heads // sh.model))     # ceil: GSPMD padding
    n_q = 16
    # q,k,v,o tensors RW once each (repeated-KV layout, head-sharded)
    qkvo = 4 * t_loc * nh_loc * hd * BF16 * 2
    # flash: each q chunk re-reads its causal KV prefix -> ~n_q/2 passes
    kv_rereads = 2 * t_loc * nh_loc * hd * BF16 * (n_q / 2)
    # residual + norms on the (t, d) stream
    stream = 4 * t_loc * d * BF16
    fwd = qkvo + kv_rereads + stream
    if not training:
        return fwd
    # remat recompute + backward (dq,dk,dv + second kv sweep)
    return fwd * 2 + (qkvo + kv_rereads)


def _mlp_layer_bytes(cfg: ArchConfig, t_loc: int, sh: Shards,
                     training: bool) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    ff_loc = max(1, ff // sh.model)
    fwd = (2 * t_loc * ff_loc * BF16          # gate*up hidden RW
           + 2 * t_loc * d * BF16)            # in/out stream
    return fwd * 3 if training else fwd


def _moe_layer_bytes(cfg: ArchConfig, t_loc: int, sh: Shards,
                     training: bool) -> float:
    moe = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    e_loc = max(1, moe.num_experts // sh.model)
    t_glob = t_loc * sh.batch
    cap = max(1, int(moe.top_k * t_glob * moe.capacity_factor
                     / moe.num_experts))
    cap_loc = max(1, cap // sh.batch)
    # router logits + one-hot cumsum + dispatch/combine buffers
    route = t_loc * moe.num_experts * (F32 + 4)          # logits + position
    buf = e_loc * cap_loc * d * BF16 * 2 * 2             # dispatch+combine RW
    hidden = e_loc * cap_loc * (ff // 1) * BF16 * 2      # expert hidden
    fwd = route + buf + hidden
    if moe.dense_residual:
        ffr = moe.dense_residual_ff // sh.model
        fwd += 2 * t_loc * max(ffr, 1) * BF16 + 2 * t_loc * d * BF16
    return fwd * 3 if training else fwd


def _ssm_layer_bytes(cfg: ArchConfig, t_loc: int, sh: Shards,
                     training: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di_loc = max(1, s.d_inner(d) // sh.model)
    nh_loc = max(1, s.n_heads(d) // sh.model)
    gs = s.n_groups * s.d_state
    # z, x streams + conv + B,C,dt + chunked states
    streams = (2 * t_loc * di_loc * BF16 * 2     # z, x RW
               + 2 * t_loc * gs * BF16 * 2       # B, C
               + t_loc * nh_loc * F32 * 2)       # dt
    chunk = max(s.chunk, 1)
    n_chunks = max(1, t_loc // max(chunk, 1))
    states = n_chunks * nh_loc * s.head_dim * s.d_state * F32 * 2
    scores = t_loc * chunk * nh_loc * F32        # intra-chunk quadratic blocks
    fwd = streams + states + scores + 2 * t_loc * d * BF16
    return fwd * 3 if training else fwd


def _embed_loss_bytes(cfg: ArchConfig, t_loc: int, sh: Shards,
                      training: bool) -> float:
    v_loc = max(1, cfg.vocab // sh.model)
    d = cfg.d_model
    emb = cfg.vocab * d // (sh.model) * BF16          # table read (sharded)
    onehot = t_loc * v_loc * BF16
    logits = t_loc * v_loc * (BF16 + F32)             # logits + f32 shifted
    fwd = emb + onehot + logits + t_loc * d * BF16
    if not training:
        return fwd
    return fwd * 2 + logits                           # bwd softmax pass


def _param_opt_bytes(cfg: ArchConfig, sh: Shards, training: bool) -> float:
    n_loc = cfg.param_count() / (sh.model * (sh.fsdp if training else 1))
    if not training:
        # serving: params sharded over model only, read once
        return cfg.param_count() / sh.model * BF16
    reads = 3 * BF16          # fwd + remat + bwd
    grad = 2 * F32            # write + read
    opt = 4 * F32             # m RW + v RW
    upd = BF16                # param write
    return n_loc * (reads + grad + opt + upd)


def _cache_bytes(cfg: ArchConfig, shape: InputShape, sh: Shards) -> float:
    """Decode: the whole cache is read once per token (+1 position write)."""
    b_loc = max(1, shape.global_batch // sh.batch)
    s_ctx = shape.seq_len
    hd = cfg.resolved_head_dim()
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            kv_loc = max(1, cfg.n_kv_heads // sh.model) \
                if cfg.n_kv_heads % sh.model == 0 else cfg.n_kv_heads
            seq_shard = 1
            if shape.global_batch < sh.batch:      # batch unshardable ->
                seq_shard = sh.batch               # kv_seq sharding
            total += 2 * b_loc * (s_ctx / seq_shard) * kv_loc * hd * BF16
        else:
            s = cfg.ssm
            nh_loc = max(1, s.n_heads(cfg.d_model) // sh.model)
            total += b_loc * nh_loc * s.head_dim * s.d_state * F32 * 2
            total += b_loc * (s.d_conv - 1) * (
                s.d_inner(cfg.d_model) // sh.model + 2 * s.n_groups
                * s.d_state) * BF16
    return total


def memory_bytes(cfg: ArchConfig, shape: InputShape,
                 multi_pod: bool = False) -> Dict[str, float]:
    """Per-device HBM bytes for one step of this cell."""
    sh = Shards.for_mesh(multi_pod)
    training = shape.kind == "train"
    if shape.kind == "decode":
        t_loc = max(1, shape.global_batch // sh.batch)   # 1 token/seq
    else:
        t_loc = shape.global_batch * shape.seq_len // sh.batch

    layers = 0.0
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind == "attn" and shape.kind != "decode":
            layers += _attn_layer_bytes(cfg, t_loc, shape.seq_len, sh,
                                        training)
        elif kind == "mamba" and shape.kind != "decode":
            layers += _ssm_layer_bytes(cfg, t_loc, sh, training)
        if cfg.d_ff > 0 and shape.kind != "decode":
            if cfg.moe is not None and i % cfg.moe_every == 0:
                layers += _moe_layer_bytes(cfg, t_loc, sh, training)
            else:
                layers += _mlp_layer_bytes(cfg, t_loc, sh, training)

    out = {
        "params_opt": _param_opt_bytes(cfg, sh, training),
        "layers": layers,
        "embed_loss": _embed_loss_bytes(cfg, t_loc, sh, training),
        "cache": _cache_bytes(cfg, shape, sh) if shape.kind == "decode"
                 else 0.0,
    }
    out["total"] = sum(out.values())
    return out
