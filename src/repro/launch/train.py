"""End-to-end launcher.

Two paths, per the paper's kind:
  * `w2v`  — the paper's workload: FULL-W2V embedding training (default).
  * `lm`   — any assigned architecture (reduced or full), synthetic tokens.

Examples:
  PYTHONPATH=src python -m repro.launch.train w2v --vocab 400000 --epochs 2
  PYTHONPATH=src python -m repro.launch.train lm --arch qwen3-8b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import logging
import sys

import jax
import numpy as np

from repro.kernels import registry


def _tables_shards(tables: str) -> int:
    """The ``shards=N`` clause of a ``--tables`` spec, parsed textually so
    :func:`main` can synthesize host devices *before* jax initializes its
    backends (the full parse lives in ``kernels.tables``, which imports
    jax)."""
    import re
    m = re.search(r"(?:^|,)\s*shards\s*=\s*(\d+)", tables or "")
    return int(m.group(1)) if m else 0


def run_w2v(args) -> int:
    import hashlib

    from repro import frontends
    from repro.configs.w2v import W2VConfig
    from repro.core.quality import evaluate
    from repro.core.trainer import TrainSession
    from repro.data.prefetch import AsyncBatchingPipeline, make_pipeline

    cfg = W2VConfig(dim=args.dim, epochs=args.epochs, min_count=1,
                    subsample_t=0.0, negatives=args.negatives,
                    window=args.window,
                    sentences_per_batch=args.sentences_per_batch,
                    max_sentence_len=args.max_sentence_len,
                    tile_windows=args.tile_windows,
                    tile_gemm_windows=args.tile_gemm_windows,
                    pad_len=args.pad_len,
                    prefetch_workers=args.prefetch_workers,
                    prefetch_depth=args.prefetch_depth,
                    prefetch_mode=args.prefetch_mode,
                    vocab_shard=bool(args.vocab_shard),
                    hot_vocab_frac=args.hot_vocab_frac,
                    tables=args.tables)
    # every workload rides the same engine: the frontend adapts a corpus
    # (words, graph walks, documents, subword bags) into the batch schema
    # and attaches its table extras to the pipeline (DESIGN.md §12)
    workload = frontends.get(args.workload).build(
        cfg, vocab=args.vocab, clusters=args.clusters,
        sentences=args.sentences,
        p=args.node2vec_p, q=args.node2vec_q,
        walk_length=args.walk_length, walks_per_node=args.walks_per_node,
        docs=args.docs, buckets=args.subword_buckets, seed=0)
    cfg, corpus = workload.cfg, workload.corpus
    pipe = make_pipeline(corpus, cfg)
    workload.attach(pipe)
    extras = (f" (+{pipe.extra_rows} {args.workload} rows)"
              if pipe.extra_rows else "")
    print(f"workload={args.workload} vocab={pipe.vocab.size}{extras} "
          f"params={2 * pipe.table_rows * cfg.dim / 1e6:.1f}M "
          f"words/epoch={pipe.epoch_words}")
    if isinstance(pipe, AsyncBatchingPipeline):
        print(f"pipeline=async(workers={pipe.workers} depth={pipe.depth} "
              f"mode={pipe.mode})")
    else:
        print("pipeline=sync")
    mesh = None
    n_shards = max(args.vocab_shard, _tables_shards(args.tables))
    if n_shards > 1:
        from repro.launch.mesh import make_host_mesh
        if jax.device_count() < n_shards:
            print(f"error: {n_shards}-shard tables need {n_shards} "
                  f"devices, have {jax.device_count()}", file=sys.stderr)
            return 2
        mesh = make_host_mesh(model=1)
    trainer = TrainSession(pipe, cfg, backend=args.backend, mesh=mesh,
                           ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every)
    print(f"backend={trainer.backend}")
    if trainer.spec.is_mixed:
        s = trainer.spec
        print(f"tables: hot={s.hot_dtype} cold={s.cold_dtype} "
              f"master_copy={s.master_copy}")
    if trainer.placement is not None:
        p = trainer.placement
        print(f"vocab_shard: hot={p.hot} cold={p.cold} shards={p.n_shards} "
              f"rows/device={p.rows_per_device} "
              f"(replicated would be {p.vocab_size})")
    if trainer.resumed_step is not None:
        print(f"resumed from checkpoint batch {trainer.resumed_step} "
              f"({trainer.state.words_seen:,} words seen)")
    resilient = (args.max_restarts > 0 or args.step_timeout > 0
                 or args.health_every > 0)
    if resilient:
        trainer.train_resilient(
            max_batches=args.max_batches,
            max_restarts=args.max_restarts or 3,
            step_timeout_s=args.step_timeout,
            health_every=args.health_every,
            reset_after=args.reset_after)
        r = trainer.last_report
        print(f"resilience: restarts={r.restarts} rollbacks={r.rollbacks} "
              f"health_failures={r.health_failures} timeouts={r.timeouts} "
              f"skipped={r.batches_skipped} "
              f"recovery_seconds={r.recovery_seconds:.3f}")
    else:
        trainer.train(max_batches=args.max_batches)
    if args.ckpt_dir:
        print("checkpoint:", trainer.save_checkpoint())
    print(f"throughput: {trainer.words_per_sec:,.0f} words/sec "
          f"({trainer.state.words_seen:,} words) "
          f"device_busy_frac={trainer.device_busy_frac:.3f}")
    # bit-exactness witness: identical configs must print identical digests
    # regardless of prefetch_workers (CI's determinism smoke greps this).
    # Covers every table leaf — hot, cold, and int8 scales — so quantized
    # storage (keyed stochastic rounding included) is held to the same
    # bit-determinism bar as f32
    digest = hashlib.sha1()
    st = trainer.state
    for part in (st.w_in, st.w_out, st.cold_in, st.cold_out,
                 st.scale_in, st.scale_out):
        if part is not None:
            digest.update(np.asarray(part).tobytes())
    print(f"final_digest={digest.hexdigest()}")
    if corpus.clusters is not None:
        inv = np.zeros(pipe.vocab.size, dtype=int)
        for w, i in pipe.vocab.ids.items():
            inv[i] = corpus.clusters[w]
        # frontend extras (doc rows, n-gram buckets) sit past the
        # vocabulary — cluster quality is a word/node-vector property
        metrics = evaluate(trainer.embeddings()[:pipe.vocab.size], inv)
        print("quality:", {k: round(v, 4) for k, v in metrics.items()})
    return 0


def run_lm(args) -> int:
    import jax.numpy as jnp

    from repro.configs.base import get_arch, get_smoke
    from repro.train.loop import LoopConfig, Trainer
    from repro.train.optim import AdamWConfig

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    loop = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      microbatches=args.microbatches)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    trainer = Trainer(cfg, opt, loop, batch=args.batch, seq=args.seq)
    out = trainer.train()
    losses = out["losses"]
    print(f"final step {out['final_step']}; loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}")
    return 0


def main() -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    w = sub.add_parser("w2v")
    from repro import frontends
    w.add_argument("--workload", default="w2v",
                   choices=frontends.names(),
                   help="workload frontend (DESIGN.md §12): plain w2v, "
                        "node2vec random walks, PV-DM doc2vec, or "
                        "fastText-style subword bags — all through the "
                        "same engine, batching, sharding, and serving")
    w.add_argument("--node2vec-p", type=float, default=1.0,
                   help="node2vec return parameter (1/p weight on "
                        "backtracking to the previous node)")
    w.add_argument("--node2vec-q", type=float, default=0.5,
                   help="node2vec in-out parameter (1/q weight on "
                        "exploring away; q<1 favors communities)")
    w.add_argument("--walk-length", type=int, default=40,
                   help="node2vec: nodes per walk")
    w.add_argument("--walks-per-node", type=int, default=10,
                   help="node2vec: walks started from each node")
    w.add_argument("--docs", type=int, default=64,
                   help="doc2vec: number of synthetic documents")
    w.add_argument("--subword-buckets", type=int, default=4096,
                   help="subword: hashed n-gram bucket rows appended "
                        "past the vocabulary")
    w.add_argument("--vocab", type=int, default=8192)
    w.add_argument("--clusters", type=int, default=64)
    w.add_argument("--sentences", type=int, default=20000)
    w.add_argument("--dim", type=int, default=128)
    w.add_argument("--window", type=int, default=5)
    w.add_argument("--negatives", type=int, default=5)
    w.add_argument("--epochs", type=int, default=2)
    w.add_argument("--sentences-per-batch", type=int, default=2048)
    w.add_argument("--max-sentence-len", type=int, default=64)
    w.add_argument("--max-batches", type=int, default=None)
    w.add_argument("--tile-windows", type=int, default=1,
                   help="T: windows fused per kernel step (DESIGN.md §4)")
    w.add_argument("--tile-gemm-windows", type=int, default=4,
                   help="G: windows per GEMM group inside a tile")
    w.add_argument("--pad-len", type=int, default=0,
                   help="padded batch length L (0: min(max-sentence-len, "
                        "1024))")
    w.add_argument("--prefetch-workers", type=int, default=0,
                   help="host pipeline workers; 0 = synchronous batching, "
                        ">0 overlaps batching with device updates "
                        "(bit-identical stream, DESIGN.md §4.1)")
    w.add_argument("--prefetch-depth", type=int, default=2,
                   help="bounded prefetch queue: finalized batches allowed "
                        "in flight ahead of the device")
    w.add_argument("--prefetch-mode", default="thread",
                   choices=("thread", "process"),
                   help="worker kind: threads (numpy finalize releases the "
                        "GIL) or processes (python-heavy encode)")
    w.add_argument("--vocab-shard", type=int, nargs="?", const=1, default=0,
                   metavar="N",
                   help="replicate the Zipf-hot vocabulary head and shard "
                        "the cold tail over the mesh data axis "
                        "(DESIGN.md §8); scales trainable vocabulary with "
                        "device count. With a value N > 1, runs over N "
                        "shards (on CPU, N fake host devices are "
                        "synthesized); bare flag = 1-shard layout")
    w.add_argument("--hot-vocab-frac", type=float, default=0.0,
                   help="replicated hot head as a fraction of V "
                        "(0: smallest prefix covering ~90%% of corpus "
                        "occurrences)")
    w.add_argument("--tables", default="",
                   help="table storage spec (DESIGN.md §11), e.g. "
                        "'hot=bf16:frac=0.1,cold=int8,shards=4': per-table "
                        "storage dtypes (f32/bf16 hot, f32/bf16/int8 cold "
                        "with per-row scales), shard count, exchange "
                        "flavor (exchange=exact|dense), and master=1 for "
                        "the f32 master-copy fallback. Subsumes "
                        "--vocab-shard/--hot-vocab-frac, which seed its "
                        "defaults; unsupported backend×dtype combinations "
                        "are rejected at resolve time")
    # choices come from the backend registry, so every registered kernel
    # variant — pipelined, tiled, interpret — is reachable from the CLI
    w.add_argument("--backend", default="auto",
                   choices=registry.cli_choices(),
                   help="kernel backend; 'auto' resolves per platform and "
                        "tile-windows against the registry descriptors")
    w.add_argument("--ckpt-dir", default=None,
                   help="checkpoint directory (resumes from the latest "
                        "checkpoint when one exists)")
    w.add_argument("--ckpt-every", type=int, default=0,
                   help="checkpoint every N batches (0: only at exit when "
                        "--ckpt-dir is set)")
    # resilience (DESIGN.md §9): any nonzero flag below drives the run
    # through TrainSupervisor (restore + bit-exact replay on failure)
    w.add_argument("--max-restarts", type=int, default=0,
                   help="supervised recovery: restore the latest good "
                        "checkpoint and replay on step failure, up to N "
                        "restarts per failure burst (0: supervision off "
                        "unless another resilience flag is set)")
    w.add_argument("--step-timeout", type=float, default=0.0,
                   help="watchdog: a batch exceeding this many seconds is "
                        "treated as a failed step (0: no timeout)")
    w.add_argument("--health-every", type=int, default=0,
                   help="probe the tables for NaN/divergence every N "
                        "batches, rolling back on failure (0: no probe)")
    w.add_argument("--reset-after", type=int, default=0,
                   help="refill the restart budget after N consecutive "
                        "good batches (0: budget is cumulative)")
    w.set_defaults(fn=run_w2v)

    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--smoke", action="store_true")
    l.add_argument("--steps", type=int, default=100)
    l.add_argument("--batch", type=int, default=8)
    l.add_argument("--seq", type=int, default=128)
    l.add_argument("--lr", type=float, default=3e-4)
    l.add_argument("--microbatches", type=int, default=1)
    l.add_argument("--ckpt-dir", default=None)
    l.add_argument("--ckpt-every", type=int, default=50)
    l.set_defaults(fn=run_lm)

    args = ap.parse_args()
    n_shards = max(getattr(args, "vocab_shard", 0),
                   _tables_shards(getattr(args, "tables", "")))
    if n_shards > 1:
        # synthesize the fake host devices the sharded run needs BEFORE
        # jax initializes its backends (first devices()/dispatch call);
        # import order alone has not initialized them yet
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={n_shards}")
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
