import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, record memory/cost/collective analysis.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) — the
512 fake host devices are locked in at jax init, which is why the XLA_FLAGS
assignment above precedes every other import.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --multi-pod
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, cells, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops_for
from repro.launch.steps import build_cell


def _compile(cfg, shape_name, mesh, param_dtype, microbatches,
             zero_stage=3, rule_overrides=None):
    import jax.numpy as jnp
    jit, args, rules = build_cell(cfg, shape_name, mesh,
                                  param_dtype=getattr(jnp, param_dtype),
                                  microbatches=microbatches,
                                  zero_stage=zero_stage,
                                  rule_overrides=rule_overrides)
    return jit.lower(*args).compile()


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 1, param_dtype: str = "bfloat16",
             verbose: bool = True, cfg=None, zero_stage: int = 3,
             rule_overrides=None, tag: str = "", extrap: bool = True) -> dict:
    import dataclasses

    cfg = cfg or get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.perf_counter()
    compiled = _compile(cfg, shape_name, mesh, param_dtype, microbatches,
                        zero_stage, rule_overrides)
    t_compile = time.perf_counter() - t0

    # --- loop-corrected cost extrapolation -------------------------------
    # XLA cost_analysis counts while-loop (layer-scan) bodies ONCE — for
    # both the forward and the remat'd backward scan, so even deltas over
    # the scanned compile are wrong. The analysis compiles therefore use
    # scan_layers=False (python-unrolled blocks; all intra-block loops are
    # already statically unrolled — flash attention, SSD chunks,
    # microbatches), at 2 and 3 blocks: cost(nb) = base + nb * per_block,
    # which is exact for homogeneous stacks (calibrated against analytic
    # matmul FLOPs — see EXPERIMENTS.md §Roofline).
    pat_len = len(cfg.hybrid_pattern) if cfg.hybrid_pattern else 1
    nb_full = cfg.n_layers // pat_len
    if not extrap:
        # compile-success + memory proof only (multi-pod runs: the roofline
        # table is single-pod per the assignment)
        from repro.launch.costmodel import memory_bytes
        terms = analyze(compiled,
                        model_flops=model_flops_for(cfg, shape) / n_dev)
        mem_model = memory_bytes(cfg, shape, multi_pod)
        mem = compiled.memory_analysis()
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": n_dev, "status": "ok", "tag": tag or "baseline",
            "extrapolated": False,
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            "roofline": terms.as_dict(),
        }
        if verbose:
            print(f"[{arch} × {shape_name} × {result['mesh']}] compile "
                  f"{t_compile:.0f}s OK (no-extrap); memory:",
                  result["memory_analysis"])
        return result
    t0 = time.perf_counter()
    c2 = analyze(_compile(
        dataclasses.replace(cfg, n_layers=2 * pat_len, scan_layers=False),
        shape_name, mesh, param_dtype, microbatches, zero_stage,
        rule_overrides))
    c3 = analyze(_compile(
        dataclasses.replace(cfg, n_layers=3 * pat_len, scan_layers=False),
        shape_name, mesh, param_dtype, microbatches, zero_stage,
        rule_overrides))
    t_extrap = time.perf_counter() - t0

    def extrap(f2, f3):
        per_block = f3 - f2
        base = f2 - 2 * per_block
        return max(base + nb_full * per_block, 0.0)

    from repro.launch.costmodel import memory_bytes

    terms = analyze(compiled,
                    model_flops=model_flops_for(cfg, shape) / n_dev)
    raw = terms.as_dict()
    terms.flops = extrap(c2.flops, c3.flops)
    # memory term: analytic TPU-fusion-aware model (the CPU backend's HLO
    # leaves elementwise chains unfused and overestimates HBM traffic
    # 5-20x — EXPERIMENTS.md §Roofline caveats). HLO bytes kept in
    # raw_hlo_costs for reference.
    mem_model = memory_bytes(cfg, shape, multi_pod)
    raw["hlo_bytes_extrapolated"] = extrap(c2.bytes_accessed,
                                           c3.bytes_accessed)
    terms.bytes_accessed = mem_model["total"]
    terms.coll_bytes = extrap(c2.coll_bytes, c3.coll_bytes)
    terms.coll_breakdown = {
        k: extrap(c2.coll_breakdown.get(k, 0.0), c3.coll_breakdown.get(k, 0.0))
        for k in set(c2.coll_breakdown) | set(c3.coll_breakdown)}
    mem = compiled.memory_analysis()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "status": "ok",
        "tag": tag or "baseline",
        "variant": {"zero_stage": zero_stage, "microbatches": microbatches,
                    "remat_policy": cfg.remat_policy,
                    "rule_overrides": repr(rule_overrides)},
        "compile_s": round(t_compile, 1),
        "extrap_compile_s": round(t_extrap, 1),
        "raw_hlo_costs": {k: raw[k] for k in
                          ("flops", "bytes_accessed", "coll_bytes",
                           "hlo_bytes_extrapolated")},
        "memory_model": mem_model,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "roofline": terms.as_dict(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {result['mesh']}] "
              f"compile {t_compile:.0f}s | "
              f"t_comp {terms.t_compute*1e3:.2f}ms "
              f"t_mem {terms.t_memory*1e3:.2f}ms "
              f"t_coll {terms.t_collective*1e3:.2f}ms "
              f"-> {terms.bottleneck}-bound, "
              f"roofline_frac {terms.roofline_frac:.3f}")
        print("  memory_analysis:", result["memory_analysis"])
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-dtype", default="bfloat16")
    ap.add_argument("--no-extrap", action="store_true",
                    help="compile + memory proof only (skip cost compiles)")
    ap.add_argument("--zero-stage", type=int, default=3)
    ap.add_argument("--remat-policy", default=None,
                    choices=[None, "nothing", "dots"])
    ap.add_argument("--repl-qo", action="store_true",
                    help="replicate q/o projections over model "
                         "(kills uneven-head reshard gathers)")
    ap.add_argument("--bf16-reduce", action="store_true",
                    help="bf16 partial sums on row-parallel projections "
                         "(halves TP stream all-reduce wire bytes)")
    ap.add_argument("--pure-dp", action="store_true",
                    help="map the whole mesh to ZeRO data parallelism "
                         "(no TP) — for models too small for 16-way TP")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch, shape in cells():
            todo.append((arch, shape, False))
            if args.both_meshes:
                todo.append((arch, shape, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        todo = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    overrides = {}
    if args.repl_qo:
        overrides["head_dim"] = (None,)
    if args.pure_dp:
        from repro.distributed.sharding import PURE_DP_OVERRIDES
        overrides.update(PURE_DP_OVERRIDES)
    overrides = overrides or None
    for arch, shape, mp in todo:
        try:
            import dataclasses as _dc
            cfg = get_arch(arch)
            if args.remat_policy:
                cfg = _dc.replace(cfg, remat_policy=args.remat_policy)
            if args.bf16_reduce:
                cfg = _dc.replace(cfg, bf16_reduce=True)
            res = run_cell(arch, shape, mp, args.microbatches,
                           args.param_dtype, cfg=cfg,
                           zero_stage=args.zero_stage,
                           rule_overrides=overrides, tag=args.tag,
                           extrap=not args.no_extrap)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e)}
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
