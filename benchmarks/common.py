"""Shared helpers for the benchmark suite.

CPU-container caveat (DESIGN.md §6): wall-clock numbers here are CPU
numbers — meaningful *relative to each other* (the paper's Fig 6 story),
while the memory-demand and arithmetic-intensity tables are analytic/HLO
derived and runtime-independent (the paper's Table 4 / Fig 1 story).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.w2v import W2VConfig
from repro.data.batching import BatchingPipeline, plan_tiles
from repro.data.corpus import synthetic_cluster_corpus, synthetic_zipf_corpus
from repro.kernels import ops
from repro.kernels.registry import StepInputs
from repro.kernels.tables import Tables


def bench_cfg(**kw) -> W2VConfig:
    base = dict(dim=128, window=5, negatives=5, epochs=1, min_count=1,
                subsample_t=0.0, sentences_per_batch=256,
                max_sentence_len=64)
    base.update(kw)
    return W2VConfig(**base)


def bench_pipeline(vocab=2000, sentences=2048, seed=0,
                   cfg: W2VConfig | None = None):
    cfg = cfg or bench_cfg()
    corpus = synthetic_zipf_corpus(vocab_size=vocab, n_sentences=sentences,
                                   mean_len=24, seed=seed)
    return BatchingPipeline(corpus, cfg), cfg, corpus


# ---------------------------------------------------------------------------
# Shared W2V training loop for quality measurements (used by bench_quality
# and bench_tile_sweep, so both measure the identical procedure).
# ---------------------------------------------------------------------------
def train_w2v(update: Callable, pipe: BatchingPipeline, cfg: W2VConfig,
              epochs: int, pad_len: int = 48) -> np.ndarray:
    """Train with linear LR decay; `update(wi, wo, batch, lr)` does one
    batch. Returns the input embeddings."""
    from repro.core.trainer import init_state

    st = init_state(pipe.vocab.size, cfg)
    wi, wo = st.w_in, st.w_out
    words_seen, total = 0, pipe.epoch_words * epochs
    for _ in range(epochs):
        for b in pipe.batches(pad_len=pad_len):
            lr = jnp.float32(
                cfg.lr * max(1 - words_seen / total, cfg.min_lr_frac))
            wi, wo = update(wi, wo, b, lr)
            words_seen += b.n_words
    return np.asarray(wi)


def w2v_seq_update(backend: str, cfg: W2VConfig) -> Callable:
    """Sequential-backend update through the engine API (`ops.step`)."""
    def update(wi, wo, b, lr):
        step = StepInputs(jnp.asarray(b.tokens), jnp.asarray(b.negs),
                          jnp.asarray(b.lengths), jnp.asarray(lr))
        out = ops.step(Tables(w_in=wi, w_out=wo), step, cfg, backend=backend)
        return out.w_in, out.w_out
    return update


def w2v_tiled_update(tile: int, cfg: W2VConfig, use_batch_plan: bool = False,
                     gemm_windows: int = 0) -> Callable:
    """Tiled-oracle update; `use_batch_plan` consumes the pipeline's own
    plan (tile-shared negatives, cfg.tile_windows path), otherwise a plan
    is built for the batch's per-window negatives (isolates the ordering
    relaxation from the sampling change)."""
    import dataclasses
    cfg = dataclasses.replace(cfg, tile_gemm_windows=gemm_windows)

    def update(wi, wo, b, lr):
        p = b.plan if (use_batch_plan and b.plan is not None) else \
            plan_tiles(b.tokens, b.negs, b.lengths, tile)
        step = StepInputs(jnp.asarray(b.tokens), jnp.asarray(b.negs),
                          jnp.asarray(b.lengths), jnp.asarray(lr),
                          jnp.asarray(p.uniq), jnp.asarray(p.scatter),
                          jnp.asarray(p.ucount), jnp.asarray(p.strict))
        out = ops.step(Tables(w_in=wi, w_out=wo), step, cfg,
                       backend="jnp_tiled")
        return out.w_in, out.w_out
    return update


def time_fn(fn: Callable[[], None], warmup: int = 1, iters: int = 3
            ) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# Analytic per-window HBM traffic model (paper Fig. 3 / Table 4 analogue).
# Counts embedding-row float traffic to/from HBM per context window.
# ---------------------------------------------------------------------------
def traffic_per_window(impl: str, w_f: int, n_neg: int, d: int) -> float:
    k = 2 * w_f           # context words per window
    m = n_neg + 1         # output rows per window
    if impl == "naive":            # accSGNS-like: RW per *pair*
        return (2 * d + 2 * d) * k * m
    if impl == "matrix":           # pWord2Vec-like: RW per window
        return 2 * d * k + 2 * d * m
    if impl == "full_register":    # negatives cached for their window only
        # ctx rows still RW per window; out rows RW once per window
        return 2 * d * k + 2 * d * m
    if impl == "fullw2v":          # lifetime ring buffer: ctx RW once/lifetime
        return 2 * d * 1 + 2 * d * m      # amortized: 1 ctx row per slide
    raise ValueError(impl)


def epoch_traffic_gb(impl: str, words: int, w_f: int, n_neg: int,
                     d: int) -> float:
    """Bytes per epoch (f32 rows), one window per corpus word."""
    return traffic_per_window(impl, w_f, n_neg, d) * 4 * words / 1e9
