"""Tile-size sweep for the window-tile batched kernel (DESIGN.md §4).

For T ∈ {1, 2, 4, 8, 16} this measures, on a Zipf corpus with the paper's
subsampling enabled:

  * per-window DMA count and GEMM invocations — replayed exactly from the
    host tile plan (`plan_costs` mirrors the kernel's runtime guards, so
    these are the counts the interpret-mode kernel issues),
  * the reduction factor vs the sequential (T=1) kernel,
  * strict-tile fraction and the VMEM scratch footprint,
  * embedding quality (cluster separation) trained with the tiled oracle —
    the ordering-relaxation cost of T>1.

The acceptance gate for this PR: ≥2× DMA + GEMM reduction at T=8 with
quality within 1% of the sequential baseline at T ≤ 8.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import (bench_cfg, fmt_row, train_w2v,
                               w2v_seq_update, w2v_tiled_update)
from repro.core.quality import evaluate
from repro.data.batching import BatchingPipeline, plan_costs, plan_tiles
from repro.data.corpus import synthetic_cluster_corpus, synthetic_zipf_corpus
from repro.kernels.fullw2v import tiled_scratch_rows

TILES = (1, 2, 4, 8, 16)
QUALITY_EPOCHS = 8     # compare converged runs (relaxation affects early
                       # epochs most; the gate is end quality)


def _vmem_bytes(tile: int, w_f: int, n_neg: int, d: int,
                gemm_windows: int = 0) -> int:
    """Scratch footprint of `_kernel_tiled` (same dims as the kernel)."""
    rows = sum(tiled_scratch_rows(tile, w_f, n_neg, gemm_windows).values())
    return rows * d * 4


def _cost_sweep() -> Dict[int, Dict[str, float]]:
    corpus = synthetic_zipf_corpus(vocab_size=2000, n_sentences=512,
                                   mean_len=48, seed=0)
    out: Dict[int, Dict[str, float]] = {}
    for t in TILES:
        cfg = bench_cfg(subsample_t=1e-3, max_sentence_len=96,
                        tile_windows=t)
        pipe = BatchingPipeline(corpus, cfg)
        batch = next(pipe.batches(pad_len=96))
        plan = batch.plan if batch.plan is not None else plan_tiles(
            batch.tokens, batch.negs, batch.lengths, 1)
        costs = plan_costs(plan, batch.lengths, cfg.negatives)
        # strict fraction over *active* tiles only (tiles wholly past the
        # sentence end are always non-strict and would bias this low)
        nt = plan.n_tiles
        act = (np.arange(nt)[None, :] * t) < batch.lengths[:, None]
        costs["strict_frac"] = (float(plan.strict[act].mean())
                                if act.any() else 0.0)
        costs["vmem_bytes"] = _vmem_bytes(t, cfg.fixed_window,
                                          cfg.negatives, cfg.dim)
        out[t] = costs
    return out


def _quality_sweep() -> Dict[int, float]:
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=400, mean_len=14, seed=0)
    out: Dict[int, float] = {}
    for t in TILES:
        cfg = bench_cfg(dim=64, sentences_per_batch=128,
                        max_sentence_len=48, tile_windows=t)
        pipe = BatchingPipeline(corpus, cfg)
        update = (w2v_tiled_update(t, cfg, use_batch_plan=True) if t > 1
                  else w2v_seq_update("jnp", cfg))
        emb = train_w2v(update, pipe, cfg, epochs=QUALITY_EPOCHS)
        inv = np.zeros(pipe.vocab.size, dtype=int)
        for w, i in pipe.vocab.ids.items():
            inv[i] = corpus.clusters[w]
        out[t] = evaluate(emb, inv, seed=1)["separation"]
    return out


def run() -> List[str]:
    costs = _cost_sweep()
    quality = _quality_sweep()
    base = costs[1]
    q_base = quality[1]
    rows = []
    for t in TILES:
        c = costs[t]
        dma_red = base["dma_per_window"] / c["dma_per_window"]
        gemm_red = base["gemms_per_window"] / c["gemms_per_window"]
        rows.append(fmt_row(
            f"tile_sweep/T{t}", 0.0,
            f"dma_per_window={c['dma_per_window']:.2f} "
            f"gemms_per_window={c['gemms_per_window']:.3f} "
            f"dma_reduction_vs_T1={dma_red:.2f} "
            f"gemm_reduction_vs_T1={gemm_red:.2f} "
            f"strict_frac={c['strict_frac']:.3f} "
            f"vmem_kib={c['vmem_bytes'] / 1024:.0f} "
            f"separation={quality[t]:.3f} "
            f"quality_ratio_vs_T1={quality[t] / max(q_base, 1e-9):.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
