"""Per-architecture reduced-config step timings on CPU (framework health
metric — the full-scale numbers live in the dry-run roofline table)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, time_fn
from repro.configs import get_smoke, list_archs
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.train.optim import AdamWConfig, adamw_init


def run() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    B, S = 2, 32
    for arch in list_archs():
        cfg = get_smoke(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw_init(params)
        step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)),
                       donate_argnums=(0, 1))
        batch = {"tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        if cfg.prefix_len:
            batch["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(B, cfg.prefix_len, cfg.d_model)),
                jnp.float32)
        params, opt_state, m = step(params, opt_state, batch)  # compile
        jax.block_until_ready(m["loss"])

        t0 = time.perf_counter()
        iters = 3
        for _ in range(iters):
            params, opt_state, m = step(params, opt_state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / iters
        tok_s = B * S / dt
        rows.append(fmt_row(f"lm_step/{arch}", dt * 1e6,
                            f"tokens_per_sec={tok_s:.0f} "
                            f"loss={float(m['loss']):.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
