"""Paper Fig. 6/7 analogue: training throughput (words/sec) per
implementation on the same synthetic corpus.

Implementations (DESIGN.md §6): naive (accSGNS-like), matrix
(pWord2Vec-like), FULL-W2V jnp oracle, FULL-W2V Pallas kernel
(interpret mode — correctness-speed only on CPU, hence benchmarked on a
reduced slice and reported separately).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp

from benchmarks.common import (bench_cfg, bench_pipeline, fmt_row,
                               w2v_seq_update)
from repro.core.baselines import matrix_sgns, naive_sgns
from repro.kernels import ops
from repro.kernels.registry import StepInputs
from repro.kernels.tables import Tables


def run() -> List[str]:
    pipe, cfg, _ = bench_pipeline(vocab=2000, sentences=256)
    w_f = cfg.fixed_window
    batches = list(pipe.batches(pad_len=64))
    rows = []

    impls = {
        "naive_accSGNS_like": lambda wi, wo, b: naive_sgns(
            wi, wo, jnp.asarray(b.tokens), jnp.asarray(b.negs),
            jnp.asarray(b.lengths), jnp.float32(0.025), w_f),
        "matrix_pWord2Vec_like": lambda wi, wo, b: matrix_sgns(
            wi, wo, jnp.asarray(b.tokens), jnp.asarray(b.negs),
            jnp.asarray(b.lengths), jnp.float32(0.025), w_f),
        "fullw2v_jnp": lambda wi, wo, b, _u=w2v_seq_update("jnp", cfg):
            _u(wi, wo, b, jnp.float32(0.025)),
    }

    for name, fn in impls.items():
        from repro.core.trainer import init_state
        st = init_state(pipe.vocab.size, cfg)
        wi, wo = st.w_in, st.w_out
        # warmup (compile)
        wi, wo = fn(wi, wo, batches[0])
        wi.block_until_ready()
        # the naive per-pair baseline is ~1000x slower on CPU: measure a
        # single batch for it, the full set for the fast impls
        bench_batches = batches[:1] if name.startswith("naive") else batches
        t0 = time.perf_counter()
        words = 0
        for b in bench_batches:
            wi, wo = fn(wi, wo, b)
            words += b.n_words
        wi.block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(fmt_row(f"throughput/{name}",
                            dt / max(len(bench_batches), 1) * 1e6,
                            f"words_per_sec={words / dt:.0f}"))

    # Pallas interpret mode: one small batch (it is a Python interpreter)
    from repro.core.trainer import init_state
    st = init_state(pipe.vocab.size, cfg)
    small = batches[0]
    sl = slice(0, 8)
    t0 = time.perf_counter()
    step = StepInputs(jnp.asarray(small.tokens[sl]),
                      jnp.asarray(small.negs[sl]),
                      jnp.asarray(small.lengths[sl]), jnp.float32(0.025))
    out = ops.step(Tables(w_in=st.w_in, w_out=st.w_out), step, cfg,
                   backend="pallas_interpret")
    wi = out.w_in
    wi.block_until_ready()
    dt = time.perf_counter() - t0
    words = int(small.lengths[sl].sum())
    rows.append(fmt_row("throughput/fullw2v_pallas_interpret",
                        dt * 1e6,
                        f"words_per_sec={words / dt:.0f}"
                        f" (interpret-mode: correctness only)"))
    rows.extend(_overlap_rows())
    return rows


def _overlap_rows() -> List[str]:
    """Overlap efficiency of the async host pipeline under a real training
    session (DESIGN.md §4.1): device-busy fraction (1 - time blocked on the
    host pipeline) and prefetch queue depth, sync vs async on the same
    seed — the streams (and final tables) are bit-identical, only the wall
    clock moves.

    CPU-container caveat (DESIGN.md §6): the "device" here is XLA-CPU
    sharing cores with the workers, so the update dominates and words/sec
    moves within noise; the discriminating signal on this box is
    ``fetch_wait_frac`` (host-stall share of wall time) and the queue
    depth. On a real accelerator the host share is the whole story —
    that is what the batching/async rows measure in isolation."""
    import dataclasses
    import os

    from repro.core.trainer import TrainSession
    from repro.data.corpus import synthetic_zipf_corpus
    from repro.data.prefetch import make_pipeline

    corpus = synthetic_zipf_corpus(vocab_size=5_000, n_sentences=2048,
                                   mean_len=24, seed=0)
    workers = max(2, min(4, os.cpu_count() or 2))
    rows = []
    for name, n_workers in (("sync", 0), (f"async_w{workers}", workers)):
        cfg = bench_cfg(sentences_per_batch=256, epochs=1,
                        prefetch_workers=n_workers, prefetch_depth=4)
        pipe = make_pipeline(corpus, cfg)
        sess = TrainSession(pipe, cfg, backend="jnp")
        sess.train(max_batches=1)       # compile outside the clock
        sess.train(epochs=1)
        depth = (f" mean_queue_depth={pipe.prefetch.mean_depth:.2f}"
                 if n_workers else "")
        rows.append(fmt_row(
            f"throughput/overlap_{name}", sess.wall_seconds * 1e6,
            f"words_per_sec={sess.words_per_sec:.0f} "
            f"device_busy_frac={sess.device_busy_frac:.3f} "
            f"fetch_wait_frac={1 - sess.device_busy_frac:.3f}" + depth))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
