"""Paper Fig. 1 analogue: arithmetic intensity (FLOPs/byte) per W2V
implementation, from the analytic per-window model — the roofline x-axis
the paper uses to show FULL-W2V's climb out of the memory-bound region.

FLOPs per window are IDENTICAL across implementations (same math):
  corr K×(N+1)×d ×2, sigmoid ≈ 4·K·(N+1), two update GEMMs ×2 each
Bytes differ by reuse policy (bench_memory traffic model) — so intensity
ratios equal traffic ratios, exactly the paper's Figure 1 structure.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import fmt_row, traffic_per_window

W_F, N_NEG, DIM = 3, 5, 128


def window_flops(w_f: int = W_F, n: int = N_NEG, d: int = DIM) -> float:
    k, m = 2 * w_f, n + 1
    gemms = 3 * 2 * k * m * d          # corr + d_ctx + d_out
    sigm = 4 * k * m
    return gemms + sigm


def run() -> List[str]:
    rows = []
    fl = window_flops()
    intens = {}
    for impl in ["naive", "matrix", "full_register", "fullw2v"]:
        b = traffic_per_window(impl, W_F, N_NEG, DIM) * 4
        intens[impl] = fl / b
        rows.append(fmt_row(f"roofline/{impl}", 0.0,
                            f"flops_per_byte={fl / b:.3f}"))
    rows.append(fmt_row(
        "roofline/intensity_gain_vs_naive", 0.0,
        f"gain={intens['fullw2v'] / intens['naive']:.1f}x "
        f"(paper: 16-24x vs GPU baselines)"))
    # v5e ridge point: 197e12 / 819e9 ≈ 241 flops/byte — W2V stays
    # memory-bound; the win is moving bytes out of HBM into VMEM reuse.
    rows.append(fmt_row("roofline/v5e_ridge", 0.0, "flops_per_byte=240.5"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
