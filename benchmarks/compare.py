"""CI perf-gate: compare a current bench trajectory against a baseline.

Two classes of check, mirroring the repo's standing gates:

  * **throughput** — any row carrying ``words_per_sec`` that exists in both
    baseline and current must not regress by more than ``--max-regression``
    (default 20%). New rows (no baseline) pass with a notice.
  * **quality** — the tile-sweep's tiled-vs-sequential ratio
    (``tile_sweep/T*`` rows, ``quality_ratio_vs_T1``) must stay within the
    existing 1% gate (``--quality-delta``) for T <= ``--quality-max-tile``,
    checked on the *current* run alone, so a quality break fails even on
    the bootstrap run that has no baseline yet.
  * **serving** — any row carrying ``qps`` (the bench_serve batch-size
    sweep) must not regress by more than ``--max-regression`` vs
    baseline (same bar as training throughput), with ``p99_us`` growth
    bounded by ``--max-p99-growth``; the serve chaos row's ``dropped``
    and ``torn`` counters must be 0 on the *current* run alone.
  * **exchange traffic** — any row carrying ``exchange_bytes`` (the
    request-exact per-device bytes from bench_memory's vocab-shard table)
    must not grow by more than ``--max-exchange-growth`` vs baseline; and
    on the current run alone, ``exchange_bytes`` must never exceed its
    ``exchange_bytes_dense`` sibling — request-exact exceeding the dense
    collectives means the bucket planner's padding regressed.
  * **resilience** — any row carrying ``digest_match`` (the
    bench_resilience chaos rows) must report 1 on the *current* run alone
    (recovery is bit-exact, DESIGN.md §9); ``restarts`` must not exceed
    the baseline's (fault schedules are deterministic, so more restarts
    means the recovery loop started thrashing), and ``recovery_seconds``
    must not grow by more than ``--max-recovery-growth``.

Exit status is the contract: 0 = gate passed (including the bootstrap case
of no baseline files), 1 = regression. ``--simulate-regression 0.25`` scales
current words/sec down by 25% before checking — the knob used once in the
PR description to demonstrate the gate actually fails, then reverted.

Usage (CI):
    python -m benchmarks.compare --baseline baseline/ \
        --current BENCH_ci.batching.json BENCH_ci.tile_sweep.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List


def load_rows(paths: List[str]) -> Dict[str, dict]:
    """Merge the ``rows`` of every trajectory JSON in `paths`; directories
    are expanded to the BENCH_*.json files inside them."""
    rows: Dict[str, dict] = {}
    for path in paths:
        if os.path.isdir(path):
            rows.update(load_rows(
                sorted(glob.glob(os.path.join(path, "*.json")))))
            continue
        with open(path) as f:
            data = json.load(f)
        rows.update(data.get("rows", {}))
    return rows


def check_throughput(baseline: Dict[str, dict], current: Dict[str, dict],
                     max_regression: float) -> List[str]:
    failures = []
    for name, cur in sorted(current.items()):
        wps = cur.get("words_per_sec")
        if not isinstance(wps, (int, float)):
            continue
        base = baseline.get(name, {}).get("words_per_sec")
        if not isinstance(base, (int, float)) or base <= 0:
            print(f"  [new] {name}: words_per_sec={wps:.0f} (no baseline)")
            continue
        ratio = wps / base
        status = "ok" if ratio >= 1.0 - max_regression else "REGRESSED"
        print(f"  [{status}] {name}: {base:.0f} -> {wps:.0f} words/sec "
              f"({(ratio - 1) * 100:+.1f}%)")
        if status == "REGRESSED":
            failures.append(
                f"{name}: words_per_sec fell {(1 - ratio) * 100:.1f}% "
                f"(> {max_regression * 100:.0f}% allowed)")
    return failures


def check_exchange(baseline: Dict[str, dict], current: Dict[str, dict],
                   max_growth: float) -> List[str]:
    failures = []
    for name, cur in sorted(current.items()):
        xb = cur.get("exchange_bytes")
        if not isinstance(xb, (int, float)):
            continue
        dense = cur.get("exchange_bytes_dense")
        if isinstance(dense, (int, float)) and xb > dense:
            print(f"  [REGRESSED] {name}: exchange_bytes={xb:.0f} exceeds "
                  f"dense path ({dense:.0f})")
            failures.append(
                f"{name}: request-exact exchange moves more bytes "
                f"({xb:.0f}) than the dense collectives ({dense:.0f})")
            continue
        base = baseline.get(name, {}).get("exchange_bytes")
        if not isinstance(base, (int, float)) or base <= 0:
            print(f"  [new] {name}: exchange_bytes={xb:.0f} (no baseline)")
            continue
        ratio = xb / base
        ok = ratio <= 1.0 + max_growth
        print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
              f"{base:.0f} -> {xb:.0f} bytes ({(ratio - 1) * 100:+.1f}%)")
        if not ok:
            failures.append(
                f"{name}: exchange_bytes grew {(ratio - 1) * 100:.1f}% "
                f"(> {max_growth * 100:.0f}% allowed)")
    return failures


def check_resilience(baseline: Dict[str, dict], current: Dict[str, dict],
                     max_recovery_growth: float) -> List[str]:
    failures = []
    for name, cur in sorted(current.items()):
        match = cur.get("digest_match")
        if not isinstance(match, (int, float)):
            continue
        if match != 1:
            print(f"  [REGRESSED] {name}: digest_match={match:.0f} — "
                  f"recovery is no longer bit-exact")
            failures.append(f"{name}: chaos recovery digest mismatch")
            continue
        base = baseline.get(name, {})
        restarts, base_restarts = cur.get("restarts"), base.get("restarts")
        if (isinstance(restarts, (int, float))
                and isinstance(base_restarts, (int, float))
                and restarts > base_restarts):
            print(f"  [REGRESSED] {name}: restarts {base_restarts:.0f} -> "
                  f"{restarts:.0f} on a deterministic fault schedule")
            failures.append(
                f"{name}: restarts grew {base_restarts:.0f} -> "
                f"{restarts:.0f} (recovery loop thrashing)")
            continue
        rec, base_rec = (cur.get("recovery_seconds"),
                         base.get("recovery_seconds"))
        if (isinstance(rec, (int, float)) and isinstance(
                base_rec, (int, float)) and base_rec > 0):
            ratio = rec / base_rec
            ok = ratio <= 1.0 + max_recovery_growth
            print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
                  f"recovery {base_rec:.3f}s -> {rec:.3f}s "
                  f"({(ratio - 1) * 100:+.0f}%)")
            if not ok:
                failures.append(
                    f"{name}: recovery_seconds grew "
                    f"{(ratio - 1) * 100:.0f}% "
                    f"(> {max_recovery_growth * 100:.0f}% allowed)")
            continue
        print(f"  [ok] {name}: digest_match=1"
              + ("" if base else " (no baseline)"))
    return failures


def check_serving(baseline: Dict[str, dict], current: Dict[str, dict],
                  max_regression: float, max_p99_growth: float
                  ) -> List[str]:
    failures = []
    for name, cur in sorted(current.items()):
        # strict current-run invariants: the serve chaos row must report
        # zero dropped and zero torn queries (like digest_match)
        dropped, torn = cur.get("dropped"), cur.get("torn")
        if isinstance(dropped, (int, float)) or isinstance(
                torn, (int, float)):
            bad = (dropped or 0) or (torn or 0)
            print(f"  [{'REGRESSED' if bad else 'ok'}] {name}: "
                  f"dropped={dropped:.0f} torn={torn:.0f}")
            if bad:
                failures.append(
                    f"{name}: serve chaos dropped={dropped:.0f} "
                    f"torn={torn:.0f} (both must be 0)")
            continue
        qps = cur.get("qps")
        if not isinstance(qps, (int, float)):
            continue
        base = baseline.get(name, {})
        base_qps = base.get("qps")
        if not isinstance(base_qps, (int, float)) or base_qps <= 0:
            print(f"  [new] {name}: qps={qps:.0f} (no baseline)")
            continue
        ratio = qps / base_qps
        ok = ratio >= 1.0 - max_regression
        print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
              f"{base_qps:.0f} -> {qps:.0f} qps ({(ratio - 1) * 100:+.1f}%)")
        if not ok:
            failures.append(
                f"{name}: qps fell {(1 - ratio) * 100:.1f}% "
                f"(> {max_regression * 100:.0f}% allowed)")
            continue
        p99, base_p99 = cur.get("p99_us"), base.get("p99_us")
        if (isinstance(p99, (int, float))
                and isinstance(base_p99, (int, float)) and base_p99 > 0):
            ratio = p99 / base_p99
            ok = ratio <= 1.0 + max_p99_growth
            print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
                  f"p99 {base_p99:.0f} -> {p99:.0f} us "
                  f"({(ratio - 1) * 100:+.0f}%)")
            if not ok:
                failures.append(
                    f"{name}: p99_us grew {(ratio - 1) * 100:.0f}% "
                    f"(> {max_p99_growth * 100:.0f}% allowed)")
    return failures


def check_mixed_precision(current: Dict[str, dict], quality_delta: float,
                          max_exchange_ratio: float) -> List[str]:
    """DESIGN.md §11 gates, both on the *current* run alone (each mixed
    bench row carries its own f32 sibling, so no baseline drift): quantized
    exchange must actually shrink the wire (``exchange_reduction_vs_f32``
    at or below ``max_exchange_ratio`` — int8 is ~0.26x at d=128, bf16
    0.5x), and mixed-precision training quality must stay within the same
    1% separation bar as the tiled kernels."""
    failures = []
    for name, cur in sorted(current.items()):
        ratio = cur.get("exchange_reduction_vs_f32")
        if isinstance(ratio, (int, float)):
            ok = ratio <= max_exchange_ratio
            print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
                  f"exchange_reduction_vs_f32={ratio:.3f}x "
                  f"(<= {max_exchange_ratio:.2f}x required)")
            if not ok:
                failures.append(
                    f"{name}: quantized exchange at {ratio:.3f}x of f32 "
                    f"bytes (> {max_exchange_ratio:.2f}x allowed — storage "
                    f"dtype is not reaching the wire)")
        ratio = cur.get("mixed_vs_f32_separation_ratio")
        if isinstance(ratio, (int, float)):
            ok = ratio >= 1.0 - quality_delta
            print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
                  f"mixed_vs_f32_separation_ratio={ratio:.4f}")
            if not ok:
                failures.append(
                    f"{name}: mixed/f32 separation ratio {ratio:.4f} below "
                    f"{1.0 - quality_delta:.2f} gate")
    return failures


def check_quality(current: Dict[str, dict], quality_delta: float,
                  max_tile: int) -> List[str]:
    failures = []
    for name, cur in sorted(current.items()):
        m = re.fullmatch(r"tile_sweep/T(\d+)", name)
        if not m or int(m.group(1)) > max_tile:
            continue
        ratio = cur.get("quality_ratio_vs_T1")
        if not isinstance(ratio, (int, float)):
            continue
        ok = ratio >= 1.0 - quality_delta
        print(f"  [{'ok' if ok else 'REGRESSED'}] {name}: "
              f"quality_ratio_vs_T1={ratio:.4f}")
        if not ok:
            failures.append(
                f"{name}: tiled/sequential quality ratio {ratio:.4f} "
                f"below {1.0 - quality_delta:.2f} gate")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", nargs="*", default=[],
                    help="baseline trajectory JSONs (or directories); "
                         "empty/missing = bootstrap run, throughput checks "
                         "are skipped")
    ap.add_argument("--current", nargs="+", required=True,
                    help="current trajectory JSONs (or directories)")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="allowed fractional words_per_sec drop (0.20=20%%)")
    ap.add_argument("--quality-delta", type=float, default=0.01,
                    help="allowed tiled-vs-sequential quality loss")
    ap.add_argument("--quality-max-tile", type=int, default=8,
                    help="largest T the quality gate applies to")
    ap.add_argument("--max-mixed-exchange-ratio", type=float, default=0.55,
                    help="required ceiling on quantized-vs-f32 exchange "
                         "bytes (current run; int8 at d=128 is ~0.26x, "
                         "bf16 0.50x — 0.55 catches a scale or dtype "
                         "falling off the wire)")
    ap.add_argument("--max-exchange-growth", type=float, default=0.20,
                    help="allowed fractional exchange_bytes growth vs "
                         "baseline (0.20=20%%); the exact<=dense invariant "
                         "is checked regardless")
    ap.add_argument("--max-p99-growth", type=float, default=1.0,
                    help="allowed fractional serve p99_us growth vs "
                         "baseline (1.0=100%%; tail latency is wall-clock "
                         "noisy on shared CI runners); qps is gated at "
                         "--max-regression and dropped/torn strictly")
    ap.add_argument("--max-recovery-growth", type=float, default=1.0,
                    help="allowed fractional recovery_seconds growth vs "
                         "baseline (1.0=100%%; recovery time is wall-clock "
                         "noisy); digest_match and restart counts are "
                         "checked strictly regardless")
    ap.add_argument("--simulate-regression", type=float, default=0.0,
                    help="scale current words_per_sec down by this fraction "
                         "(gate-failure demonstration only)")
    args = ap.parse_args()

    baseline = load_rows([p for p in args.baseline if os.path.exists(p)])
    current = load_rows(args.current)
    if not current:
        print("perf-gate: no current rows found", file=sys.stderr)
        return 1
    if args.simulate_regression:
        print(f"!! simulating a {args.simulate_regression * 100:.0f}% "
              f"slowdown on every current words_per_sec row")
        for row in current.values():
            if isinstance(row.get("words_per_sec"), (int, float)):
                row["words_per_sec"] *= 1.0 - args.simulate_regression

    failures: List[str] = []
    print("perf-gate: throughput (words_per_sec vs baseline)")
    if baseline:
        failures += check_throughput(baseline, current, args.max_regression)
    else:
        print("  no baseline trajectory — bootstrap run, skipping")
    print("perf-gate: quality (tiled vs sequential, current run)")
    failures += check_quality(current, args.quality_delta,
                              args.quality_max_tile)
    print("perf-gate: mixed precision (quantized exchange + quality, "
          "current run)")
    failures += check_mixed_precision(current, args.quality_delta,
                                      args.max_mixed_exchange_ratio)
    print("perf-gate: exchange traffic (request-exact bytes)")
    failures += check_exchange(baseline, current, args.max_exchange_growth)
    print("perf-gate: resilience (chaos recovery, bit-exact + bounded)")
    failures += check_resilience(baseline, current,
                                 args.max_recovery_growth)
    print("perf-gate: serving (qps/p99 vs baseline, chaos dropped/torn)")
    failures += check_serving(baseline, current, args.max_regression,
                              args.max_p99_growth)

    if failures:
        print("\nperf-gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nperf-gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
