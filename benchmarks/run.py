# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_throughput  — Fig. 6/7  (training words/sec per implementation)
  bench_memory      — Table 4   (per-epoch memory demand per implementation)
  bench_quality     — Table 7   (embedding quality equivalence + tiled gate)
  bench_batching    — Table 1   (host batching speed)
  bench_roofline    — Fig. 1    (arithmetic intensity per implementation)
  bench_lm_step     — (this repo) per-arch reduced-config step timings
  bench_tile_sweep  — (this repo) DESIGN.md §4 window-tile sweep
  bench_resilience  — (this repo) DESIGN.md §9 chaos-schedule recovery
  bench_serve       — (this repo) DESIGN.md §10 serving QPS/p50/p99 + swap
  bench_workloads   — (this repo) DESIGN.md §12 per-frontend words/sec + quality

Run: PYTHONPATH=src python -m benchmarks.run [--only <name>] [--out FILE]

Besides the CSV on stdout, every run writes a ``BENCH_<step>.json``
trajectory file (step = commit count, overridable via --step/$BENCH_STEP)
with all rows parsed into key=value dicts — so future PRs can diff
throughput words/sec, quality scores, and tile-sweep reductions against
this one.
"""
import argparse
import json
import os
import subprocess
import sys
import traceback


def _git_step() -> int:
    try:
        out = subprocess.run(
            ["git", "rev-list", "--count", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return int(out.stdout.strip())
    except Exception:  # noqa: BLE001
        return 0


def _parse_derived(derived: str) -> dict:
    """Parse 'k1=v1 k2=v2 ...' fragments of a CSV row; non k=v tokens are
    collected under 'note'."""
    out, notes = {}, []
    for tok in derived.split():
        if "=" in tok:
            key, val = tok.split("=", 1)
            try:
                out[key] = float(val.rstrip("x%"))
            except ValueError:
                out[key] = val
        else:
            notes.append(tok)
    if notes:
        out["note"] = " ".join(notes)
    return out


# suite name -> module benchmarks.bench_<name>; single registry that both
# --only's choices and the run loop derive from
SUITE_NAMES = ("roofline", "memory", "batching", "throughput", "quality",
               "tile_sweep", "lm_step", "resilience", "serve", "workloads")


def _load_suites() -> dict:
    import importlib
    return {name: importlib.import_module(f"benchmarks.bench_{name}")
            for name in SUITE_NAMES}


def _only_arg(value: str):
    """--only accepts a comma-separated subset of suites (the perf-gate
    runs batching+tile_sweep in one invocation -> one trajectory file)."""
    names = tuple(v.strip() for v in value.split(",") if v.strip())
    bad = [n for n in names if n not in SUITE_NAMES]
    if bad:
        raise argparse.ArgumentTypeError(
            f"unknown suite(s) {bad}; choose from {SUITE_NAMES}")
    return names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, type=_only_arg,
                    metavar="SUITE[,SUITE...]",
                    help=f"subset of suites to run; any of {SUITE_NAMES}")
    ap.add_argument("--step", type=int, default=None,
                    help="trajectory step id (default: $BENCH_STEP or "
                         "git commit count)")
    ap.add_argument("--out", default=None,
                    help="trajectory JSON path (default: BENCH_<step>.json "
                         "in the repo root)")
    args = ap.parse_args()

    suites = _load_suites()
    step = args.step if args.step is not None else int(
        os.environ.get("BENCH_STEP", _git_step()))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # partial (--only) runs get their own file so they never clobber the
    # full trajectory future PRs diff against
    suffix = f".{'-'.join(args.only)}" if args.only else ""
    out_path = args.out or os.path.join(repo, f"BENCH_{step}{suffix}.json")

    print("name,us_per_call,derived")
    failed = 0
    traj = {"step": step, "rows": {}, "errors": []}
    for name, mod in suites.items():
        if args.only and name not in args.only:
            continue
        try:
            for row in mod.run():
                print(row)
                rname, us, derived = row.split(",", 2)
                traj["rows"][rname] = {"us_per_call": float(us),
                                       **_parse_derived(derived)}
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            traj["errors"].append(name)
            print(f"{name},nan,ERROR")
    with open(out_path, "w") as f:
        json.dump(traj, f, indent=1, sort_keys=True)
    print(f"# trajectory -> {out_path}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
