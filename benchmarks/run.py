# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_throughput  — Fig. 6/7  (training words/sec per implementation)
  bench_memory      — Table 4   (per-epoch memory demand per implementation)
  bench_quality     — Table 7   (embedding quality equivalence)
  bench_batching    — Table 1   (host batching speed)
  bench_roofline    — Fig. 1    (arithmetic intensity per implementation)
  bench_lm_step     — (this repo) per-arch reduced-config step timings

Run: PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_batching,
        bench_lm_step,
        bench_memory,
        bench_quality,
        bench_roofline,
        bench_throughput,
    )
    suites = {
        "roofline": bench_roofline,
        "memory": bench_memory,
        "batching": bench_batching,
        "throughput": bench_throughput,
        "quality": bench_quality,
        "lm_step": bench_lm_step,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run():
                print(row)
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
