"""Serving load harness (DESIGN.md §10): QPS / latency / swap / chaos.

Rows:

  * ``serve/b{B}``  — the batcher + jitted sharded top-k driven at
    saturation with request batches of B queries: ``qps`` plus per-batch
    ``p50_us``/``p99_us`` latency. The QPS-vs-batch-size curve is the
    serving analogue of the training kernel's words/sec-vs-tile curve:
    bigger batches amortize the table sweep until the device saturates.
  * ``serve/swap``  — hot-swap cost: publish a fresh checkpoint and
    measure stage+flip latency (``swap_ms``); queries keep flowing the
    whole time (``served_during_swap``).
  * ``serve/chaos`` — the deterministic serve chaos schedule
    (:mod:`repro.serve.chaos`): watcher killed and restarted mid-swap.
    ``dropped`` and ``torn`` must be 0 — gated strictly by
    ``benchmarks/compare.py`` like ``digest_match``.

``compare.py`` gates ``qps`` (>20% drop vs baseline fails, same bar as
training words/sec) and ``p99_us`` growth.
"""
from __future__ import annotations

import time

import numpy as np

BATCH_SIZES = (1, 8, 32)
VOCAB, HOT, DIM = 4096, 512, 64
REQUESTS = 48
K = 5


def _mk_index(step=0):
    import jax
    from jax.sharding import Mesh

    from repro.distributed.vocab_placement import VocabPlacement
    from repro.serve.index import EmbeddingIndex

    rng = np.random.default_rng(7)
    table = rng.standard_normal((VOCAB, DIM)).astype(np.float32)
    placement = VocabPlacement(vocab_size=VOCAB, hot=HOT, n_shards=1)
    hot, cold = placement.split(table)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    return EmbeddingIndex._stage(placement, hot, cold, mesh, step=step)


def _drive(index, batch_size, requests=REQUESTS, window=4):
    """Closed-loop load: keep `window` full-size request batches in
    flight (enough to saturate, not enough to bury latency under queue
    backlog); returns (qps, p50_us, p99_us, batches)."""
    from repro.serve.server import EmbeddingServer

    rng = np.random.default_rng(11)
    with EmbeddingServer(index, batch_size=batch_size, deadline_ms=0.5,
                         k=K) as server:
        # one warmup round to take jit compilation off the clock
        server.neighbors(rng.integers(VOCAB, size=batch_size)
                         .astype(np.int32))
        server.latencies_us.clear()
        pending = []
        t0 = time.perf_counter()
        for i in range(requests):
            if i >= window:
                pending[i - window].wait(60.0)
            ids = rng.integers(VOCAB, size=batch_size).astype(np.int32)
            pending.append(server.submit("nn", ids))
        for req in pending[-window:]:
            req.wait(60.0)
        wall = time.perf_counter() - t0
        lat = np.asarray(server.latencies_us, np.float64)
        return (requests * batch_size / max(wall, 1e-9),
                float(np.percentile(lat, 50)),
                float(np.percentile(lat, 99)), server.batches)


def _swap_row():
    """Publish a checkpoint stream and time one staged hot-swap while a
    query load keeps running against the server."""
    import shutil
    import tempfile

    from repro.distributed.vocab_placement import VocabPlacement
    from repro.serve.chaos import _publish
    from repro.serve.server import EmbeddingServer
    from repro.serve.snapshot import SnapshotWatcher

    rng = np.random.default_rng(3)
    placement = VocabPlacement(vocab_size=VOCAB, hot=HOT, n_shards=1)
    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        table = rng.standard_normal((VOCAB, DIM)).astype(np.float32)
        _publish(tmp, 10, table, placement)
        watcher = SnapshotWatcher(tmp, poll_s=0.01)
        watcher.wait_ready()
        with EmbeddingServer(watcher, batch_size=8, deadline_ms=0.5,
                             k=K) as server:
            served_before = 0
            pending = []
            for _ in range(16):
                ids = rng.integers(VOCAB, size=8).astype(np.int32)
                pending.append(server.submit("nn", ids))
            served_before = server.served
            table2 = rng.standard_normal((VOCAB, DIM)).astype(np.float32)
            _publish(tmp, 20, table2, placement)
            t0 = time.perf_counter()
            swapped = watcher.poll_once()      # stage + flip, timed
            swap_ms = (time.perf_counter() - t0) * 1e3
            assert swapped and watcher.current().step == 20
            for req in pending:
                req.wait(60.0)
            return {"swap_ms": swap_ms,
                    "served_during_swap": server.served - served_before,
                    "load_failures": watcher.load_failures}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run():
    from repro.serve.chaos import SCHEDULES, run_serve_chaos

    for b in BATCH_SIZES:
        index = _mk_index()
        qps, p50, p99, batches = _drive(index, b)
        us = 1e6 * b / max(qps, 1e-9)
        yield (f"serve/b{b},{us:.1f},qps={qps:.0f} p50_us={p50:.0f} "
               f"p99_us={p99:.0f} batches={batches} k={K} vocab={VOCAB} "
               f"dim={DIM}")

    s = _swap_row()
    yield (f"serve/swap,{s['swap_ms'] * 1e3:.1f},"
           f"swap_ms={s['swap_ms']:.1f} "
           f"served_during_swap={s['served_during_swap']} "
           f"load_failures={s['load_failures']}")

    c = run_serve_chaos(SCHEDULES["ci"])
    yield (f"serve/chaos,{c['wall_seconds'] * 1e6:.1f},"
           f"dropped={c['dropped']} torn={c['torn']} swaps={c['swaps']} "
           f"crashes={c['crashes']} queries={c['queries']} "
           f"publishes={c['publishes']} load_failures={c['load_failures']} "
           f"wall_seconds={c['wall_seconds']}")
