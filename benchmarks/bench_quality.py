"""Paper Table 7 analogue: embedding quality per implementation on the
planted-cluster corpus. FULL-W2V (jnp + Pallas-interpret) must be
statistically equivalent to the pWord2Vec-like baseline."""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, fmt_row
from repro.core.baselines import matrix_sgns, naive_sgns
from repro.core.quality import evaluate
from repro.core.trainer import init_state
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus
from repro.kernels import ops

EPOCHS = 4


def _train(update, pipe, cfg, epochs=EPOCHS):
    st = init_state(pipe.vocab.size, cfg)
    wi, wo = st.w_in, st.w_out
    words_seen, total = 0, pipe.epoch_words * epochs
    for _ in range(epochs):
        for b in pipe.batches(pad_len=48):
            lr = cfg.lr * max(1 - words_seen / total, 1e-4)
            wi, wo = update(wi, wo, jnp.asarray(b.tokens),
                            jnp.asarray(b.negs), jnp.asarray(b.lengths),
                            jnp.float32(lr))
            words_seen += b.n_words
    return np.asarray(wi)


def run() -> List[str]:
    cfg = bench_cfg(dim=64, sentences_per_batch=128, max_sentence_len=48)
    w_f = cfg.fixed_window
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=400, mean_len=14, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    inv = np.zeros(pipe.vocab.size, dtype=int)
    for w, i in pipe.vocab.ids.items():
        inv[i] = corpus.clusters[w]

    impls = {
        "matrix_pWord2Vec_like": lambda wi, wo, t, n, ln, lr:
            matrix_sgns(wi, wo, t, n, ln, lr, w_f),
        "naive_accSGNS_like": lambda wi, wo, t, n, ln, lr:
            naive_sgns(wi, wo, t, n, ln, lr, w_f),
        "fullw2v_jnp": lambda wi, wo, t, n, ln, lr:
            ops.sgns_batch_update(wi, wo, t, n, ln, lr, w_f, backend="jnp"),
    }
    rows = []
    scores: Dict[str, Dict] = {}
    for name, fn in impls.items():
        emb = _train(fn, pipe, cfg)
        m = evaluate(emb, inv, seed=1)
        scores[name] = m
        rows.append(fmt_row(
            f"quality/{name}", 0.0,
            f"spearman={m['spearman']:.3f} separation={m['separation']:.3f} "
            f"nn_purity={m['nn_purity']:.3f}"))
    # equivalence check (Table 7's conclusion)
    a = scores["fullw2v_jnp"]["separation"]
    b = scores["matrix_pWord2Vec_like"]["separation"]
    rows.append(fmt_row(
        "quality/equivalence", 0.0,
        f"fullw2v_vs_pword2vec_separation_ratio={a / max(b, 1e-9):.3f} "
        f"(≈1.0 expected)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
