"""Paper Table 7 analogue: embedding quality per implementation on the
planted-cluster corpus. FULL-W2V (jnp + Pallas-interpret) must be
statistically equivalent to the pWord2Vec-like baseline.

The tiled variants (T ∈ {4, 8}) train on *identical* per-window negatives
as the sequential kernel, so their rows isolate the DESIGN.md §4 ordering
relaxation (fused tiles read pre-tile values); the gate is separation
within 1% of the sequential FULL-W2V run. End-to-end tiled numbers with
tile-shared negatives live in `bench_tile_sweep`.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_cfg, fmt_row, train_w2v,
                               w2v_seq_update, w2v_tiled_update)
from repro.core.baselines import matrix_sgns, naive_sgns
from repro.core.quality import evaluate
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_cluster_corpus

EPOCHS = 4
GATE_EPOCHS = 8     # the tiled gate compares *converged* runs
TILED_T = (4, 8)


def run() -> List[str]:
    cfg = bench_cfg(dim=64, sentences_per_batch=128, max_sentence_len=48)
    w_f = cfg.fixed_window
    corpus = synthetic_cluster_corpus(n_clusters=8, words_per_cluster=16,
                                      n_sentences=400, mean_len=14, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    inv = np.zeros(pipe.vocab.size, dtype=int)
    for w, i in pipe.vocab.ids.items():
        inv[i] = corpus.clusters[w]

    impls = {
        "matrix_pWord2Vec_like": lambda wi, wo, b, lr:
            matrix_sgns(wi, wo, jnp.asarray(b.tokens), jnp.asarray(b.negs),
                        jnp.asarray(b.lengths), lr, w_f),
        "naive_accSGNS_like": lambda wi, wo, b, lr:
            naive_sgns(wi, wo, jnp.asarray(b.tokens), jnp.asarray(b.negs),
                       jnp.asarray(b.lengths), lr, w_f),
        "fullw2v_jnp": w2v_seq_update("jnp", cfg),
    }
    rows = []
    scores: Dict[str, Dict] = {}
    for name, fn in impls.items():
        emb = train_w2v(fn, pipe, cfg, epochs=EPOCHS)
        m = evaluate(emb, inv, seed=1)
        scores[name] = m
        rows.append(fmt_row(
            f"quality/{name}", 0.0,
            f"spearman={m['spearman']:.3f} separation={m['separation']:.3f} "
            f"nn_purity={m['nn_purity']:.3f}"))
    # equivalence check (Table 7's conclusion)
    a = scores["fullw2v_jnp"]["separation"]
    b = scores["matrix_pWord2Vec_like"]["separation"]
    rows.append(fmt_row(
        "quality/equivalence", 0.0,
        f"fullw2v_vs_pword2vec_separation_ratio={a / max(b, 1e-9):.3f} "
        f"(≈1.0 expected)"))
    # tiled ordering-relaxation gate (DESIGN.md §4): converged runs on
    # *identical* batch streams (fresh deterministic pipeline per run, so
    # both sides see the same subsampling + per-window negatives — the only
    # difference is kernel semantics), within 1% of sequential expected
    def fresh_pipe():
        return BatchingPipeline(corpus, cfg)

    a8 = evaluate(train_w2v(w2v_seq_update("jnp", cfg), fresh_pipe(), cfg,
                            epochs=GATE_EPOCHS), inv, seed=1)["separation"]
    for t in TILED_T:
        q = evaluate(train_w2v(w2v_tiled_update(t, cfg), fresh_pipe(), cfg,
                               epochs=GATE_EPOCHS), inv, seed=1)["separation"]
        rows.append(fmt_row(
            f"quality/tiled_T{t}_gate", 0.0,
            f"tiled_vs_sequential_separation_ratio={q / max(a8, 1e-9):.4f} "
            f"(1.00±0.01 expected)"))
    rows.append(_mixed_precision_gate(corpus, cfg, inv))
    return rows


def _mixed_precision_gate(corpus, cfg, inv) -> str:
    """DESIGN.md §11 quality gate: converged bf16-hot/int8-cold training
    (stochastic-rounding stores, keyed per-batch) must land within 1% of
    the f32 run's cluster separation. Both sides go through the same
    ``TrainSession`` path on identical deterministic batch streams, so the
    only difference is table storage precision."""
    import dataclasses

    from repro.core.trainer import TrainSession

    def separation(tables: str) -> float:
        c = dataclasses.replace(cfg, tables=tables, epochs=GATE_EPOCHS)
        sess = TrainSession(BatchingPipeline(corpus, c), c, backend="jnp")
        sess.train(epochs=GATE_EPOCHS)
        return evaluate(np.asarray(sess.embeddings()), inv,
                        seed=1)["separation"]

    f32 = separation("")
    mixed = separation("hot=bf16:frac=0.1,cold=int8,shards=1")
    return fmt_row(
        "quality/mixed_precision_gate", 0.0,
        f"mixed_vs_f32_separation_ratio={mixed / max(f32, 1e-9):.4f} "
        f"mixed_separation={mixed:.3f} f32_separation={f32:.3f} "
        f"(1.00±0.01 expected)")


if __name__ == "__main__":
    print("\n".join(run()))
