"""Recovery metrics under the deterministic chaos schedules (DESIGN.md §9).

Two rows:

  * ``resilience/ci``    — the full ``ci`` fault schedule (step exceptions,
    worker kill, checkpoint truncation, NaN injection). Derived fields
    carry ``digest_match`` (must be 1 — recovery is bit-exact), restart /
    rollback / quarantine counts, total ``recovery_seconds``, and pool
    ``heals``.
  * ``resilience/clean`` — the same workload supervised but fault-free:
    the supervision overhead witness (``restarts`` must be 0 and
    ``digest_match`` 1; ``wall_seconds`` vs the ci row bounds what the
    fault handling itself cost).

``benchmarks/compare.py`` gates these: ``digest_match`` must be 1 on the
current run, and ``restarts`` / ``recovery_seconds`` must not grow vs the
baseline trajectory.
"""
from __future__ import annotations


def _row(name: str, result: dict) -> str:
    us = result["wall_seconds"] * 1e6
    derived = (f"digest_match={result['digest_match']} "
               f"restarts={result['restarts']} "
               f"rollbacks={result['rollbacks']} "
               f"health_failures={result['health_failures']} "
               f"ckpt_quarantined={result['ckpt_quarantined']} "
               f"heals={result['heals']} "
               f"batches_skipped={result['batches_skipped']} "
               f"recovery_seconds={result['recovery_seconds']} "
               f"wall_seconds={result['wall_seconds']}")
    return f"{name},{us:.1f},{derived}"


def run():
    from repro.train.chaos import SCHEDULES, run_chaos

    yield _row("resilience/ci", run_chaos(SCHEDULES["ci"]))
    yield _row("resilience/clean", run_chaos(SCHEDULES["none"]))
