"""(this repo) DESIGN.md §12 workload frontends: per-frontend training
throughput + embedding quality through the *full* session path (frontend
build → pipeline attach → TrainSession), so a frontend regression —
slower walk generation, a doc-row slow path in the kernels, bag-gather
blowup — shows up in the same words/sec gate the plain W2V rows use.

Rows (one per registered frontend, ``w2v`` first as the baseline):

    workloads/<name>,us_per_batch,words_per_sec=... separation=...
        nn_purity=... extra_rows=...

``words_per_sec`` is gated by ``benchmarks.compare`` against the previous
trajectory exactly like the throughput suite (new rows pass with a
notice, so adding a frontend never breaks the bootstrap run).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import bench_cfg, fmt_row
from repro import frontends
from repro.core.quality import evaluate
from repro.core.trainer import TrainSession
from repro.data.batching import BatchingPipeline

WARMUP_BATCHES = 1    # jit compile + first-batch staging
TIMED_BATCHES = 8

# per-frontend corpus knobs: small enough for CI, large enough that the
# planted structure is recoverable (every corpus here carries cluster
# ground truth, so the quality columns are comparable across frontends)
KNOBS = dict(
    vocab=512, clusters=16, sentences=1536, mean_len=20,       # w2v, subword
    buckets=1024,                                              # subword
    docs=48, sents_per_doc=24, words_per_cluster=32,           # doc2vec
    communities=12, nodes_per=16, walks_per_node=4,            # node2vec
    walk_length=32,
)


def run() -> List[str]:
    rows = []
    for name in frontends.names():
        cfg = bench_cfg(dim=64, sentences_per_batch=64, max_sentence_len=32)
        wl = frontends.get(name).build(cfg, **KNOBS)
        pipe = BatchingPipeline(wl.corpus, wl.cfg)
        wl.attach(pipe)
        sess = TrainSession(pipe, wl.cfg, backend="jnp")
        sess.train(max_batches=WARMUP_BATCHES)
        w0 = sess.state.words_seen
        t0 = time.perf_counter()
        sess.train(max_batches=TIMED_BATCHES)
        dt = time.perf_counter() - t0
        words = sess.state.words_seen - w0
        emb = sess.embeddings()[:pipe.vocab.size]
        inv = np.zeros(pipe.vocab.size, dtype=int)
        for w, i in pipe.vocab.ids.items():
            inv[i] = wl.corpus.clusters[w]
        m = evaluate(emb, inv, seed=1)
        rows.append(fmt_row(
            f"workloads/{name}", dt * 1e6 / TIMED_BATCHES,
            f"words_per_sec={words / dt:.0f} "
            f"separation={m['separation']:.3f} "
            f"nn_purity={m['nn_purity']:.3f} "
            f"extra_rows={pipe.extra_rows}"))
    return rows
