"""Paper Table 1 analogue: host batching speed in words/sec (vocab encode +
subsample + pack + negative pre-sampling, no device work).

Rows use ``BatchingPipeline.stats``, which clocks *steady-state batching
only* — the timer starts at the first batch, so vocab/alias construction
never dilutes words/sec. The async rows exercise
``data/prefetch.py::AsyncBatchingPipeline`` with the same seed and record
the speedup, the bounded-queue depth profile, and a bitwise-match witness
against the synchronous stream (1.0 = every batch identical).
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

from benchmarks.common import bench_cfg, fmt_row
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_zipf_corpus
from repro.data.prefetch import AsyncBatchingPipeline

# modest parallelism: CI runners have 2-4 cores; more workers than cores
# only adds contention to the numbers
BENCH_WORKERS = max(2, min(4, os.cpu_count() or 2))


def _consume(pipe: BatchingPipeline, epoch: int = 0,
             reference: Optional[list] = None):
    """Drain one epoch; returns (batches, words_per_sec, n_batches,
    bitwise_match_vs_reference)."""
    batches = list(pipe.batches(pad_len=64, epoch=epoch))
    match = 1.0
    if reference is not None:
        match = float(len(batches) == len(reference) and all(
            np.array_equal(a.tokens, b.tokens)
            and np.array_equal(a.negs, b.negs)
            and np.array_equal(a.lengths, b.lengths)
            for a, b in zip(batches, reference)))
    return batches, pipe.stats.words_per_sec, len(batches), match


def run() -> List[str]:
    cfg = bench_cfg(sentences_per_batch=512)
    # ~24 batches: long enough to amortize pool start-up and measure the
    # pipelines in steady state
    corpus = synthetic_zipf_corpus(vocab_size=20_000, n_sentences=12_288,
                                   mean_len=24, seed=0)
    # one vocab for every pipeline: the rows measure batching, not build
    vocab = BatchingPipeline(corpus, cfg).vocab

    rows = []
    sync = BatchingPipeline(corpus, cfg, vocab=vocab)
    ref, wps_sync, n, _ = _consume(sync)
    rows.append(fmt_row(
        "batching/standard", sync.stats.seconds / n * 1e6,
        f"words_per_sec={wps_sync:.0f}"))

    cfg_pack = dataclasses.replace(cfg, ignore_delimiters=True)
    packed = BatchingPipeline(corpus, cfg_pack, vocab=vocab)
    _, wps_pack, n_pack, _ = _consume(packed)
    rows.append(fmt_row(
        "batching/stream_packed", packed.stats.seconds / n_pack * 1e6,
        f"words_per_sec={wps_pack:.0f}"))

    for mode in ("thread", "process"):
        apipe = AsyncBatchingPipeline(corpus, cfg, vocab=vocab,
                                      workers=BENCH_WORKERS, depth=4,
                                      mode=mode)
        _, wps, n_async, match = _consume(apipe, reference=ref)
        rows.append(fmt_row(
            f"batching/async_{mode}", apipe.stats.seconds / n_async * 1e6,
            f"words_per_sec={wps:.0f} "
            f"speedup_vs_sync={wps / max(wps_sync, 1e-9):.2f} "
            f"workers={BENCH_WORKERS} "
            f"mean_queue_depth={apipe.prefetch.mean_depth:.2f} "
            f"max_in_flight={apipe.prefetch.max_in_flight} "
            f"bitwise_match_sync={match:.0f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
