"""Paper Table 1 analogue: host batching speed in words/sec (vocab encode +
subsample + pack + negative pre-sampling, no device work)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import bench_cfg, fmt_row
from repro.data.batching import BatchingPipeline
from repro.data.corpus import synthetic_zipf_corpus


def run() -> List[str]:
    cfg = bench_cfg(sentences_per_batch=512)
    corpus = synthetic_zipf_corpus(vocab_size=20_000, n_sentences=4096,
                                   mean_len=24, seed=0)
    pipe = BatchingPipeline(corpus, cfg)
    t0 = time.perf_counter()
    words = sum(b.n_words for b in pipe.batches(pad_len=64))
    dt = time.perf_counter() - t0
    rows = [fmt_row("batching/standard", dt * 1e6,
                    f"words_per_sec={words / dt:.0f}")]

    import dataclasses
    cfg2 = dataclasses.replace(cfg, ignore_delimiters=True)
    pipe2 = BatchingPipeline(corpus, cfg2)
    t0 = time.perf_counter()
    words2 = sum(b.n_words for b in pipe2.batches(pad_len=64))
    dt2 = time.perf_counter() - t0
    rows.append(fmt_row("batching/stream_packed", dt2 * 1e6,
                        f"words_per_sec={words2 / dt2:.0f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
