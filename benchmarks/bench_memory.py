"""Paper Table 4 analogue: per-epoch memory demand per implementation.

Two views:
  * analytic — the per-window HBM row-traffic model (paper Fig. 3), scaled
    to a Text8-sized epoch (16.7M trainable words). Runtime-independent.
  * HLO — 'bytes accessed' from the compiled update for one synthetic
    sentence with all loops statically unrolled (the jnp impls use lax
    loops, so this view compiles a single-window microkernel instead).

The paper's claim being reproduced: FULL-W2V removes ≈2W_f/(2W_f+1) of
context-row traffic vs per-window implementations — ≥86% for W_f=3 — and
~8-9x total traffic vs accSGNS-like per-pair updates.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import epoch_traffic_gb, fmt_row
from repro.core.sgns import window_delta

TEXT8_WORDS = 16_718_845   # paper Table 3
W_F = 3                    # fixed width for W=5
N_NEG = 5
DIM = 128


def hlo_window_bytes() -> float:
    """bytes accessed by one compiled shared-negative window update
    (the matrix/pWord2Vec inner loop body) — cross-checks the analytic
    per-window model."""
    k, m, d = 2 * W_F, N_NEG + 1, DIM

    def one_window(ctx, out_rows):
        d_ctx, d_out = window_delta(ctx, out_rows,
                                    jnp.ones((k,), bool), jnp.float32(0.025))
        return ctx + d_ctx, out_rows + d_out

    comp = jax.jit(one_window).lower(
        jax.ShapeDtypeStruct((k, d), jnp.float32),
        jax.ShapeDtypeStruct((m, d), jnp.float32)).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def run() -> List[str]:
    rows = []
    base = None
    for impl in ["naive", "matrix", "full_register", "fullw2v"]:
        gb = epoch_traffic_gb(impl, TEXT8_WORDS, W_F, N_NEG, DIM)
        if impl == "naive":
            base = gb
        rows.append(fmt_row(
            f"memory/{impl}", 0.0,
            f"gb_per_epoch={gb:.1f} reduction_vs_naive="
            f"{(1 - gb / base) * 100:.1f}%"))
    # context-row traffic reduction (the §3.2 claim)
    ctx_matrix = 2 * DIM * 2 * W_F
    ctx_full = 2 * DIM
    rows.append(fmt_row(
        "memory/context_row_reduction", 0.0,
        f"reduction={(1 - ctx_full / ctx_matrix) * 100:.1f}% "
        f"(paper claims ~86% at W_f=3)"))
    rows.append(fmt_row(
        "memory/hlo_window_bytes", 0.0,
        f"bytes={hlo_window_bytes():.0f} analytic="
        f"{(2 * DIM * 2 * W_F + 2 * DIM * (N_NEG + 1)) * 4:.0f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
