"""Paper Table 4 analogue: per-epoch memory demand per implementation.

Two views:
  * analytic — the per-window HBM row-traffic model (paper Fig. 3), scaled
    to a Text8-sized epoch (16.7M trainable words). Runtime-independent.
  * HLO — 'bytes accessed' from the compiled update for one synthetic
    sentence with all loops statically unrolled (the jnp impls use lax
    loops, so this view compiles a single-window microkernel instead).

The paper's claim being reproduced: FULL-W2V removes ≈2W_f/(2W_f+1) of
context-row traffic vs per-window implementations — ≥86% for W_f=3 — and
~8-9x total traffic vs accSGNS-like per-pair updates.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import epoch_traffic_gb, fmt_row
from repro.core.sgns import window_delta

TEXT8_WORDS = 16_718_845   # paper Table 3
W_F = 3                    # fixed width for W=5
N_NEG = 5
DIM = 128


def hlo_window_bytes() -> float:
    """bytes accessed by one compiled shared-negative window update
    (the matrix/pWord2Vec inner loop body) — cross-checks the analytic
    per-window model."""
    k, m, d = 2 * W_F, N_NEG + 1, DIM

    def one_window(ctx, out_rows):
        d_ctx, d_out = window_delta(ctx, out_rows,
                                    jnp.ones((k,), bool), jnp.float32(0.025))
        return ctx + d_ctx, out_rows + d_out

    comp = jax.jit(one_window).lower(
        jax.ShapeDtypeStruct((k, d), jnp.float32),
        jax.ShapeDtypeStruct((m, d), jnp.float32)).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def vocab_shard_rows() -> List[str]:
    """DESIGN.md §8 headline: per-device table memory shrinks ~1/N for the
    cold tail while per-step exchange volume tracks *distinct rows per
    shard* — not V. Host-side accounting only (placement + exchange plan on
    a real Zipf batch), so the numbers are runtime-independent."""
    from repro.configs.w2v import W2VConfig
    from repro.data.batching import BatchingPipeline
    from repro.data.corpus import synthetic_zipf_corpus
    from repro.distributed.vocab_placement import VocabPlacement, \
        plan_exchange

    def setup(vocab_size):
        cfg = W2VConfig(dim=DIM, window=5, negatives=N_NEG, min_count=1,
                        subsample_t=0.0, sentences_per_batch=256,
                        max_sentence_len=64)
        corpus = synthetic_zipf_corpus(vocab_size=vocab_size,
                                       n_sentences=2048, mean_len=24, seed=0)
        pipe = BatchingPipeline(corpus, cfg)
        return pipe, next(pipe.batches(pad_len=64))

    # -- shard-count sweep at fixed V: rows/device -> hot + cold/N ----------
    pipe, batch = setup(20_000)
    v = pipe.vocab.size
    table_mb = 2 * v * DIM * 4 / 1e6     # both tables, replicated
    rows = [fmt_row(
        "memory/vocab_shard_replicated", 0.0,
        f"V={v} mb_per_device={table_mb:.1f} exchange_mb_per_step="
        f"{table_mb:.1f} (full-table pmean moves O(V) every step)")]
    for n in (1, 4, 16, 64):
        pl = VocabPlacement.plan(pipe.vocab.counts, n)
        ex = plan_exchange(batch, pl)
        per_dev_mb = 2 * pl.rows_per_device * DIM * 4 / 1e6
        distinct = max(ex.n_distinct) if ex.n_distinct else 0
        # per-device bytes both exchange flavors actually move per step
        # (DESIGN.md §8 exchange-math table): dense = the (n, R, d)
        # psum_scatter + all_gather pair; exact = the (n, C, d) bucketed
        # all_to_all pair — O(distinct) instead of O(n*R). exchange_bytes
        # is the perf-gate column (benchmarks/compare.py fails on growth
        # and on exact exceeding dense).
        dense_kb = ex.bytes_device_dense(DIM) / 1e3
        exact_kb = ex.bytes_device_exact(DIM) / 1e3
        rows.append(fmt_row(
            f"memory/vocab_shard_n{n}", 0.0,
            f"hot={pl.hot} rows_per_device={pl.rows_per_device} "
            f"mb_per_device={per_dev_mb:.2f} "
            f"cold_shrink={pl.cold / max(pl.cold_per_shard, 1):.1f}x "
            f"max_distinct_rows={distinct} "
            f"exchange_bytes={ex.bytes_device_exact(DIM):.0f} "
            f"exchange_bytes_dense={ex.bytes_device_dense(DIM):.0f} "
            f"exchange_kb_exact={exact_kb:.0f} "
            f"exchange_kb_dense={dense_kb:.0f} "
            f"exchange_shrink={dense_kb / max(exact_kb, 1e-9):.1f}x "
            f"bucket_capacity={ex.bucket_capacity} "
            f"bucket_occupancy={ex.bucket_occupancy:.2f}"))
    # -- mixed-precision wire pricing at the gate point (n=4): the exact
    # path moves rows in storage dtype (DESIGN.md §11), so int8 cold rows
    # cost d+4 wire bytes (payload + per-row f32 scale) and bf16 rows 2d —
    # vs f32's 4d. Each row carries its own f32 sibling so compare.py
    # gates the reduction within a single run (no cross-run drift). The
    # dense flavor stays f32 on the wire regardless (psum_scatter sums in
    # f32), which is exactly why the gate is on the exact path.
    pl4 = VocabPlacement.plan(pipe.vocab.counts, 4)
    ex4 = plan_exchange(batch, pl4)
    f32_bytes = ex4.bytes_device_exact(DIM)
    for dt in ("int8", "bfloat16"):
        mixed = ex4.bytes_device_exact(DIM, dtype=dt)
        table_mb = (pl4.hot * DIM * 4            # hot head stays f32 here
                    + pl4.cold_per_shard
                    * ex4.row_bytes(DIM, dt)) * 2 / 1e6
        rows.append(fmt_row(
            f"memory/vocab_shard_n4_{'bf16' if dt == 'bfloat16' else dt}",
            0.0,
            f"cold_dtype={dt} exchange_bytes={mixed:.0f} "
            f"exchange_bytes_f32={f32_bytes:.0f} "
            f"exchange_reduction_vs_f32={mixed / f32_bytes:.3f}x "
            f"wire_row_bytes={ex4.row_bytes(DIM, dt)} "
            f"mb_per_device={table_mb:.2f}"))
    # -- vocab-growth sweep at fixed shards: exchange tracks distinct rows
    # per shard (bounded by the shard's batch slice), NOT V --------------
    n = 16
    for vs in (10_000, 20_000, 40_000, 80_000):
        pipe, batch = setup(vs)
        pl = VocabPlacement.plan(pipe.vocab.counts, n)
        ex = plan_exchange(batch, pl)
        distinct = max(ex.n_distinct) if ex.n_distinct else 0
        rows.append(fmt_row(
            f"memory/vocab_shard_growth_v{pipe.vocab.size}", 0.0,
            f"shards={n} max_distinct_rows={distinct} "
            f"exchange_bytes={ex.bytes_device_exact(DIM):.0f} "
            f"exchange_bytes_dense={ex.bytes_device_dense(DIM):.0f} "
            f"bucket_occupancy={ex.bucket_occupancy:.2f} "
            f"pmean_equiv_mb={2 * pipe.vocab.size * DIM * 4 / 1e6:.1f}"))
    return rows


def run() -> List[str]:
    rows = []
    base = None
    for impl in ["naive", "matrix", "full_register", "fullw2v"]:
        gb = epoch_traffic_gb(impl, TEXT8_WORDS, W_F, N_NEG, DIM)
        if impl == "naive":
            base = gb
        rows.append(fmt_row(
            f"memory/{impl}", 0.0,
            f"gb_per_epoch={gb:.1f} reduction_vs_naive="
            f"{(1 - gb / base) * 100:.1f}%"))
    # context-row traffic reduction (the §3.2 claim)
    ctx_matrix = 2 * DIM * 2 * W_F
    ctx_full = 2 * DIM
    rows.append(fmt_row(
        "memory/context_row_reduction", 0.0,
        f"reduction={(1 - ctx_full / ctx_matrix) * 100:.1f}% "
        f"(paper claims ~86% at W_f=3)"))
    rows.append(fmt_row(
        "memory/hlo_window_bytes", 0.0,
        f"bytes={hlo_window_bytes():.0f} analytic="
        f"{(2 * DIM * 2 * W_F + 2 * DIM * (N_NEG + 1)) * 4:.0f}"))
    rows.extend(vocab_shard_rows())
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
